"""Dev check: every smoke arch does forward + train grad + prefill + decode."""
import numpy as np
import jax, jax.numpy as jnp

from repro import configs
from repro.models import lm, steps, param_count
from repro.optim import make_optimizer

B, S = 2, 32
for arch in configs.ARCH_IDS:
    cfg = configs.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.vlm_patches:
        batch["patches"] = jnp.ones((B, cfg.vlm_patches, cfg.d_model), jnp.float32) * 0.01
    if cfg.encoder is not None:
        batch["frames"] = jnp.ones((B, cfg.encoder.n_frames, cfg.d_model), jnp.float32) * 0.01

    loss, metrics = steps.loss_fn(cfg, params, batch, impl="naive")
    init, update = make_optimizer("adamw", lr=1e-3)
    opt_state = init(params)
    ts = steps.make_train_step(cfg, update, impl="naive")
    params2, opt_state, m = jax.jit(ts)(params, opt_state, 0, batch)

    # serve: prefill + 2 decode steps
    caches = lm.init_caches(cfg, B, max_seq=S + 8)
    pre = steps.make_prefill_step(cfg, impl="naive")
    kw = {}
    if cfg.vlm_patches:
        kw["patches"] = batch["patches"]
    if cfg.encoder is not None:
        kw["frames"] = batch["frames"]
    lg, caches = jax.jit(pre, static_argnames=())(params, tokens, caches, **kw)
    dec = steps.make_decode_step(cfg, impl="naive")
    tok = jnp.argmax(lg, -1)[:, None]
    for i in range(2):
        lg2, caches = jax.jit(dec)(params, caches, tok, jnp.asarray(S + i))
        tok = jnp.argmax(lg2, -1)[:, None]

    ok_loss = bool(np.isfinite(np.asarray(loss)))
    ok_m = bool(np.isfinite(np.asarray(m["loss"])))
    ok_lg = bool(np.all(np.isfinite(np.asarray(lg2))))
    print(f"{arch:22s} N={param_count(cfg):>10,}  loss={float(loss):8.4f} "
          f"train_ok={ok_m} decode_ok={ok_lg}")
print("ALL SMOKE ARCHS OK")
