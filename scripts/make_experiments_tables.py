"""Emit the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run artifacts."""

import json
import pathlib

ART = pathlib.Path("artifacts/dryrun")


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def main():
    recs = {}
    for p in sorted(ART.glob("*.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], "multi" if r["multi_pod"] else "single")] = r

    archs = sorted({k[0] for k in recs})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    print("### Dry-run matrix (status / per-device HLO memory)\n")
    print("| arch | shape | 16x16 | 2x16x16 | params/dev | state/dev | fits 16G |")
    print("|---|---|---|---|---|---|---|")
    for a in archs:
        for s in shapes:
            r1 = recs.get((a, s, "single"))
            r2 = recs.get((a, s, "multi"))
            if r1 is None:
                continue
            if r1["status"] == "skipped":
                print(f"| {a} | {s} | SKIP | SKIP | — | — | — |")
                continue
            pb = r1.get("params_bytes_device", 0) / 2**30
            sb = r1.get("state_bytes_device", 0) / 2**30
            print(f"| {a} | {s} | {r1['status']} ({r1.get('compile_s',0):.0f}s) "
                  f"| {r2['status'] if r2 else '—'} | {pb:.2f}G | {sb:.2f}G "
                  f"| {'Y' if r1.get('fits_hbm_state') else 'N'} |")

    print("\n### Roofline (single-pod 16x16; per-step seconds)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL/HLO flops | note |")
    print("|---|---|---|---|---|---|---|---|")
    NOTES = {
        "compute_s": "MXU-bound: increase per-chip batch or quantize",
        "memory_s": "HBM-bound: fuse/remat less, shrink activation IO",
        "collective_s": "ICI-bound: resharding (see §Perf)",
    }
    for a in archs:
        for s in shapes:
            r = recs.get((a, s, "single"))
            if not r or r["status"] != "ok":
                continue
            print(f"| {a} | {s} | {fmt_s(r['compute_s'])} | "
                  f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                  f"{r['dominant'].replace('_s','')} | "
                  f"{r['useful_flops_ratio']:.2f} | {NOTES[r['dominant']]} |")


if __name__ == "__main__":
    main()
