"""Dev check: reference executor vs batched jax executor, bit-exact trees."""
import numpy as np

from repro.core import TreeConfig, TreeParallelMCTS, RolloutBackend
from repro.envs import BanditTreeEnv


def run(executor: str, steps: int = 6, p: int = 8):
    env = BanditTreeEnv(fanout=4, terminal_depth=8, varying_fanout=True)
    cfg = TreeConfig(X=256, F=4, D=6, beta=1.0, vl_mode="wu")
    m = TreeParallelMCTS(cfg, env, RolloutBackend(env, max_steps=16, seed=7),
                         p=p, executor=executor, seed=3)
    for _ in range(steps):
        m.superstep()
    return m.exec.snapshot(m.tree), m.stats


ref_snap, _ = run("reference")
jax_snap, _ = run("faithful")
bad = []
for k in ref_snap:
    if k == "log_table":
        continue
    if not np.array_equal(ref_snap[k], jax_snap[k]):
        d = np.argwhere(np.asarray(ref_snap[k]) != np.asarray(jax_snap[k]))
        bad.append((k, d[:5], np.asarray(ref_snap[k]).ravel()[:8], np.asarray(jax_snap[k]).ravel()[:8]))
print("MISMATCHES:", [b[0] for b in bad] or "none — bit-exact")
for k, d, a, b in bad:
    print(k, "first diffs at", d.tolist())
print("tree size:", ref_snap["size"], jax_snap["size"])
