"""Benchmark harness — one module per paper table/figure.

  fig4   (bench_intree)     in-tree op latency vs p, CPU vs accelerated
  fig5   (bench_throughput) system throughput + breakdown
  table1 (bench_resources)  UCT accelerator memory vs VMEM budget
  extras: fixed-point precision (paper §IV-C), selection diversity
          (beyond-paper ablation), roofline summary (reads dry-run),
          multi-tree service scaling vs G (bench_service, beyond-paper).

Every line printed is ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_diversity, bench_fixedpoint, bench_intree, bench_resources,
        bench_roofline, bench_service, bench_throughput,
    )

    t0 = time.time()
    print("name,us_per_call,derived")
    bench_resources.run()
    bench_fixedpoint.run()
    bench_intree.run()
    bench_throughput.run()
    bench_service.run()
    bench_diversity.run()
    bench_roofline.run()
    print(f"# benchmarks completed in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
