"""Benchmark harness — one module per paper table/figure.

  fig4   (bench_intree)     in-tree op latency vs p, CPU vs accelerated
  fig5   (bench_throughput) system throughput + breakdown
  table1 (bench_resources)  UCT accelerator memory vs VMEM budget
  extras: fixed-point precision (paper §IV-C), selection diversity
          (beyond-paper ablation), roofline summary (reads dry-run),
          multi-tree service scaling vs G x executor x occupancy
          (bench_service, beyond-paper).

Every line printed is ``name,us_per_call,derived`` CSV, and each module's
rows are also written to ``BENCH_<name>.json`` at the repo root so the
perf trajectory is recorded commit to commit.

  python benchmarks/run.py                  # full sweep, all modules
  python benchmarks/run.py --only intree --only service
  python benchmarks/run.py --smoke          # tiny G/p, one repetition (CI)
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _write_bench_json(name: str, rows: list, elapsed_s: float,
                      smoke: bool) -> None:
    out = REPO_ROOT / f"BENCH_{name}.json"
    out.write_text(json.dumps({
        "bench": name,
        "smoke": smoke,
        "elapsed_s": round(elapsed_s, 2),
        "unix_time": int(time.time()),
        "rows": rows,
    }, indent=2) + "\n")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", action="append", default=None,
                    metavar="NAME",
                    help="run only these modules (repeatable); names are "
                         "the bench_<NAME> suffixes, e.g. intree, service")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny parameters, one repetition — CI regression "
                         "gate for the bench harness itself")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_diversity, bench_fixedpoint, bench_intree, bench_resources,
        bench_roofline, bench_service, bench_throughput,
    )
    from benchmarks.common import drain_results

    modules = [
        ("resources", bench_resources),
        ("fixedpoint", bench_fixedpoint),
        ("intree", bench_intree),
        ("throughput", bench_throughput),
        ("service", bench_service),
        ("diversity", bench_diversity),
        ("roofline", bench_roofline),
    ]
    if args.only:
        unknown = set(args.only) - {n for n, _ in modules}
        if unknown:
            ap.error(f"unknown bench module(s): {sorted(unknown)}")
        modules = [(n, m) for n, m in modules if n in args.only]

    t0 = time.time()
    print("name,us_per_call,derived")
    for name, mod in modules:
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        drain_results()
        tm = time.time()
        mod.run(**kwargs)
        _write_bench_json(name, drain_results(), time.time() - tm,
                          args.smoke)
    print(f"# benchmarks completed in {time.time()-t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
