"""Paper §IV-C validation: fixed-point vs float argmax agreement on uct
scores, and the quantization error distribution (the <0.01% claim)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line
from repro.core import fixedpoint as fx


def run(trials=20_000, fanout=36, x_nodes=48_000, seed=0):
    rng = np.random.RandomState(seed)
    agree = 0
    rel_errs = []
    for _ in range(trials):
        n_parent = rng.randint(1, x_nodes)
        n_child = rng.randint(1, n_parent + 1, size=fanout)
        q = rng.uniform(0, 1, size=fanout).astype(np.float32)
        u = np.sqrt(np.log(np.float32(n_parent)) / n_child.astype(np.float32))
        uct = q + u
        a_float = int(np.argmax(uct))
        a_fx = int(np.argmax(fx.encode(uct)))
        agree += a_float == a_fx
        rel_errs.append(np.abs(fx.decode(fx.encode(uct)) - uct) / uct)
    rate = agree / trials
    rel = float(np.mean(rel_errs))
    csv_line("fixedpoint_argmax_agreement_pct", rate * 100,
             f"mean_rel_err={rel:.2e};claim_ok={rel < 1e-4}")
    return rate, rel


if __name__ == "__main__":
    run()
