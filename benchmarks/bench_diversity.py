"""Search-diversity ablation for the beyond-paper selection variants.

Metric: unique leaves selected per superstep / p (higher = better worker
spread).  faithful (paper pipeline semantics) vs wavefront (rank-based
repulsion, chain D instead of p*D) vs relaxed (no intra-superstep
repulsion — demonstrates why repulsion is required)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_line
from repro.core import TreeConfig, TreeParallelMCTS
from benchmarks.common import NullSim
from repro.envs import BanditTreeEnv


def run(p=32, supersteps=6):
    cfg = TreeConfig(X=4096, F=6, D=8)
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    rows = []
    for ex in ("faithful", "wavefront", "relaxed"):
        m = TreeParallelMCTS(cfg, env, NullSim(), p=p, executor=ex)
        uniq = []
        for _ in range(supersteps):
            sel = m.superstep()
            uniq.append(len(np.unique(sel["leaves"])) / p)
        frac = float(np.mean(uniq[1:]))
        csv_line(f"diversity_unique_leaf_frac_{ex}", frac * 100,
                 f"frac={frac:.3f}")
        rows.append((ex, frac))
    return rows


if __name__ == "__main__":
    run()
