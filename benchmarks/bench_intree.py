"""Fig. 4 analogue: in-tree operation latency per MCTS iteration vs p.

Paper: FPGA accelerator vs CPU master process, Pong (F=6, D=9) and Gomoku
(F=36, D=5), p in 8..128.  Here: batched-jit accelerator (+ wavefront
beyond-paper variant + the arena-native Pallas kernels, interpret mode on
this CPU-only container) vs the sequential CPU reference, on a single CPU
core.  The simulation backend is a null stub so only in-tree time
(Selection + Expansion tree-half + BackUp + transfers + ST) is measured,
exactly the paper's Fig. 4 metric.  The kernel numbers measure the
serving path's executor dispatch, not TPU silicon — interpret mode runs
the kernel as jit'd jax ops, so treat them as a correctness-carrying
upper bound until a real TPU run flips kernels.ops.INTERPRET.
"""

from __future__ import annotations

from benchmarks.common import NullSim, csv_line, run_supersteps
from repro.core import TreeConfig
from repro.envs import BanditTreeEnv

# reduced X keeps the CPU reference tractable; F/D are the paper's.
PONG = TreeConfig(X=4096, F=6, D=9)
GOMOKU = TreeConfig(X=4096, F=36, D=5, beta=5.0, score_fn="puct",
                    leaf_mode="unexpanded", expand_all=True)

EXECUTORS = ("reference", "faithful", "wavefront", "pallas")


def run(n_steps=6, ps=(8, 32, 128), smoke: bool = False):
    rows = []
    benches = (("pong", PONG, 6, 12), ("gomoku", GOMOKU, 36, 8))
    if smoke:
        n_steps, ps = 2, (4,)
        benches = (("pong", TreeConfig(X=256, F=6, D=9), 6, 12),)
    for bench, cfg, fanout, depth in benches:
        env = BanditTreeEnv(fanout=fanout, terminal_depth=depth)
        for p in ps:
            base = None
            for ex in EXECUTORS:
                stats, _ = run_supersteps(cfg, env, NullSim(), p, ex, n_steps)
                us = stats.t_intree / stats.supersteps * 1e6
                if ex == "reference":
                    base = us
                speedup = base / us if base else 1.0
                csv_line(f"fig4_intree_{bench}_p{p}_{ex}", us,
                         f"speedup_vs_cpu={speedup:.2f}")
                rows.append((bench, p, ex, us, speedup))
    return rows


if __name__ == "__main__":
    run()
