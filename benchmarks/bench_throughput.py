"""Fig. 5 analogue: system throughput (simulation requests / second) and
MCTS-step breakdown (Simulation vs other operations), CPU-only reference
vs accelerated executors, with REAL simulation backends:
  pong   — software rollouts (paper: OpenAI-gym),
  gomoku — policy-value DNN inference (paper: AlphaZero-Gomoku net).
"""

from __future__ import annotations

import jax

from benchmarks.common import csv_line, run_supersteps
from repro.core import TreeConfig, RolloutBackend
from repro.envs import BanditTreeEnv, GomokuEnv
from repro.envs.policy_net import NNSimBackend, init_params

PONG = TreeConfig(X=2048, F=6, D=9)
GOMOKU = TreeConfig(X=2048, F=36, D=5, beta=5.0, score_fn="puct",
                    leaf_mode="unexpanded", expand_all=True)


def run(n_steps=4, ps=(8, 32)):
    rows = []
    env_p = BanditTreeEnv(fanout=6, terminal_depth=12)
    for p in ps:
        base = None
        for ex in ("reference", "faithful"):
            stats, wall = run_supersteps(
                PONG, env_p, RolloutBackend(env_p, max_steps=24, seed=1),
                p, ex, n_steps)
            thr = stats.sim_requests / wall
            if ex == "reference":
                base = thr
            csv_line(f"fig5_throughput_pong_p{p}_{ex}", 1e6 / thr,
                     f"req_per_s={thr:.0f};speedup={thr/base:.2f};"
                     f"sim_frac={stats.t_sim/stats.t_total:.2f}")
            rows.append(("pong", p, ex, thr))

    genv = GomokuEnv()
    nn = NNSimBackend(genv, init_params(jax.random.PRNGKey(0)))
    for p in ps:
        base = None
        for ex in ("reference", "faithful"):
            stats, wall = run_supersteps(GOMOKU, genv, nn, p, ex, n_steps,
                                         alternating=True)
            thr = stats.sim_requests / wall
            if ex == "reference":
                base = thr
            csv_line(f"fig5_throughput_gomoku_p{p}_{ex}", 1e6 / thr,
                     f"req_per_s={thr:.0f};speedup={thr/base:.2f};"
                     f"sim_frac={stats.t_sim/stats.t_total:.2f}")
            rows.append(("gomoku", p, ex, thr))
    return rows


if __name__ == "__main__":
    run()
