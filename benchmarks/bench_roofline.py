"""Roofline summary: reads the dry-run artifacts (artifacts/dryrun/*.json)
and prints the per-cell three-term roofline table (also emitted as CSV
rows for run.py)."""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import csv_line

ART = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load_records(mesh="single"):
    recs = []
    if not ART.exists():
        return recs
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def run(mesh="single"):
    recs = load_records(mesh)
    if not recs:
        print("# no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun` first")
        return []
    rows = []
    for r in recs:
        name = f"roofline_{r['arch']}_{r['shape']}_{mesh}"
        total = r["compute_s"] + 0  # step time bound = max(terms)
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / bound if bound else 0.0
        csv_line(
            name, bound * 1e6,
            f"dom={r['dominant']};compute_s={r['compute_s']:.3e};"
            f"memory_s={r['memory_s']:.3e};collective_s={r['collective_s']:.3e};"
            f"roofline_frac={frac:.3f};useful={r['useful_flops_ratio']:.2f}")
        rows.append(r)
    return rows


if __name__ == "__main__":
    run()
