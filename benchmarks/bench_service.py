"""Service-layer scaling: searches/sec and superstep latency vs G.

The arena's pitch is that G concurrent searches cost one device program
per phase instead of G — so superstep latency should grow sublinearly in
G on the jit path while the sequential reference pays the full G×.  Each
row queues 2*G single-move searches over G slots (every slot is evicted
and refilled once: admission, fused batching and eviction are all on the
measured path).

CSV: service_<executor>_G<g>, us per superstep, searches_per_sec=<v>
"""

from __future__ import annotations

import time

from repro.core import TreeConfig
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import SearchRequest, SearchService

from benchmarks.common import csv_line


def _one(executor: str, G: int, p: int = 8, budget: int = 8):
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfg = TreeConfig(X=512, F=6, D=8)

    def build():
        svc = SearchService(cfg, env, BanditValueBackend(), G=G, p=p,
                            executor=executor)
        for i in range(2 * G):
            svc.submit(SearchRequest(uid=i, seed=i, budget=budget))
        return svc

    build().run()                    # warmup (jit compile)
    svc = build()
    t0 = time.perf_counter()
    done = svc.run()
    wall = time.perf_counter() - t0
    assert len(done) == 2 * G
    us_per_superstep = wall / max(svc.stats.supersteps, 1) * 1e6
    csv_line(f"service_{executor}_G{G}", us_per_superstep,
             f"searches_per_sec={len(done) / wall:.2f}")


def run():
    for executor in ("reference", "faithful"):
        for G in (1, 2, 4, 8):
            _one(executor, G)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
