"""Service-layer scaling: searches/sec and superstep latency vs G,
swept over executor (reference / faithful jit / arena-native pallas) and
occupancy (full arena vs a few active slots, masked vs compacted).

The arena's pitch is that G concurrent searches cost one device program
per phase instead of G — so superstep latency should grow sublinearly in
G on the device paths while the sequential reference pays the full G×.
Full-occupancy rows queue 2*G single-move searches over G slots (every
slot is evicted and refilled once: admission, fused batching and eviction
are all on the measured path).  Low-occupancy rows queue G//4 searches
over the same G slots and measure the same workload twice — masked
(compact_threshold=0: idle slots execute discarded work) vs compacted
(active slots gathered into a dense sub-arena) — which is the ROADMAP's
idle-slot-waste item made measurable, on both the jit and kernel paths.

High-G rows sweep the host-expansion engine (core.expand): the per-slot
env.step loop vs one flattened step_batch across all slots, with a
service_expand_speedup_G<g> row recording the expansion-phase speedup.

service_persist_compact_* rows measure the compaction-session refactor:
the same low-occupancy stable-set workload with per-superstep
gather/scatter (the old cost model, persistent_compaction=False) vs a
persistent device-resident CompactionSession (gather once, scatter on
close) — the ROADMAP "compaction re-gathers every superstep" item made
measurable.  service_hetero_* rows drive the multi-config frontend: a
mix of two TreeConfig shape classes routed into two arena pools by
ServiceFrontend, round-robinned to completion.

service_policy_* rows sweep the SearchClient schedule policies
(round-robin / weighted-queue-depth / deadline-aware) over a
heterogeneous 3-config load, recording throughput, global ticks and the
fairness p95 admission wait from the per-pool wait histogram.
service_xpool_fuse_* rows pin the cross-pool fused Simulation batch:
under a gang policy ONE SimulationBackend.evaluate spans every advancing
pool per tick, and the row records the largest fused batch vs the
largest single-pool share inside one (fused must be strictly larger at
heterogeneous load — the acceptance gate) plus the fused-vs-per-pool
wall-clock.

service_dispatch_k_* rows sweep the fused K-superstep device dispatch
(supersteps_per_dispatch ∈ {1,2,4,8} × faithful/pallas at G=16): K>1
runs up to K supersteps per compiled lax.while_loop program instead of
returning to Python between every phase — the speedup_vs_k1 field is
the ROADMAP item 2 acceptance gate.

service_shard_D<d>_G<g> rows sweep the D-sharded arena (core/sharded.py):
the same full-occupancy refill workload at fixed G with the slots
partitioned across D per-device shard arenas (least-loaded placement,
per-shard fused dispatches), recording searches/sec and speedup_vs_d1.
Run under XLA_FLAGS=--xla_force_host_platform_device_count=4 to give
each shard its own device; on a 1-device host the map wraps and the rows
measure the partition overhead alone.

service_obs_overhead_G<g> pins the observability layer's cost: the same
weighted-queue-depth heterogeneous workload with tracing + metrics
enabled vs off (enabled wall overhead must stay < 5%), plus a direct
microbench of the disabled no-op call path per superstep (CI gates this
`disabled_overhead` at < 2% — the ~0% claim, measured noise-free).

CSV: service_<executor>_G<g>_<occupancy>, us per superstep,
     searches_per_sec=<v> (+ compaction counters on low-occupancy rows)
"""

from __future__ import annotations

import time

from repro.core import TreeConfig
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import (
    POLICY_NAMES, SearchClient, SearchRequest, SearchService,
    ServiceFrontend,
)

from benchmarks.common import csv_line


def _one(executor: str, G: int, p: int = 8, budget: int = 8,
         n_req: int | None = None, compact_threshold: float = 0.0,
         tag: str = "full", X: int = 512, expansion: str = "loop"):
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfg = TreeConfig(X=X, F=6, D=8)
    n = 2 * G if n_req is None else n_req

    def build():
        svc = SearchService(cfg, env, BanditValueBackend(), G=G, p=p,
                            executor=executor,
                            compact_threshold=compact_threshold,
                            expansion=expansion)
        for i in range(n):
            svc.submit(SearchRequest(uid=i, seed=i, budget=budget))
        return svc

    build().run()                    # warmup (jit compile)
    svc = build()
    t0 = time.perf_counter()
    done = svc.run()
    wall = time.perf_counter() - t0
    assert len(done) == n
    us_per_superstep = wall / max(svc.stats.supersteps, 1) * 1e6
    derived = f"searches_per_sec={len(done) / wall:.2f}"
    if tag != "full":
        derived += (f" compacted={svc.stats.compacted_supersteps}"
                    f"/{svc.stats.supersteps}")
    csv_line(f"service_{executor}_G{G}_{tag}", us_per_superstep, derived)
    return svc.stats


def _persist_compact_rows(executors, G, p, budget, X):
    """Per-superstep vs persistent compaction on a stable active set:
    G//4 equal-budget searches admitted at once, so the membership set is
    constant until they drain and the session path pays ONE gather."""
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfg = TreeConfig(X=X, F=6, D=8)
    n = max(1, G // 4)
    for executor in executors:
        per_mode = {}
        for persistent in (False, True):
            def build():
                svc = SearchService(cfg, env, BanditValueBackend(), G=G,
                                    p=p, executor=executor,
                                    compact_threshold=0.5,
                                    persistent_compaction=persistent)
                for i in range(n):
                    svc.submit(SearchRequest(uid=i, seed=i, budget=budget))
                return svc
            build().run()                # warmup (jit compile)
            svc = build()
            t0 = time.perf_counter()
            svc.run()
            wall = time.perf_counter() - t0
            per_mode[persistent] = (
                wall / max(svc.stats.supersteps, 1) * 1e6, svc.stats)
        per_us, _ = per_mode[False]
        ses_us, s = per_mode[True]
        csv_line(
            f"service_persist_compact_{executor}_G{G}", ses_us,
            f"per_superstep_us={per_us:.1f} persistent_us={ses_us:.1f} "
            f"speedup={per_us / max(ses_us, 1e-9):.2f}x "
            f"gathers={s.session_gathers} reuses={s.session_reuses} "
            f"scatters={s.session_scatters} "
            f"compacted={s.compacted_supersteps}/{s.supersteps}")


def _hetero_rows(executors, G, p, budget, X):
    """Heterogeneous-config mix through the frontend: two shape classes,
    two arena pools, supersteps round-robinned across them."""
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfgs = (TreeConfig(X=X, F=6, D=8),
            TreeConfig(X=max(64, X // 2), F=6, D=6))
    n = 2 * G
    for executor in executors:
        def build():
            fe = ServiceFrontend(env, BanditValueBackend(), G=G, p=p,
                                 executor=executor, compact_threshold=0.5)
            for i in range(n):
                fe.submit(SearchRequest(uid=i, seed=i, budget=budget,
                                        cfg=cfgs[i % len(cfgs)]))
            return fe
        build().run()                    # warmup (jit compile)
        fe = build()
        t0 = time.perf_counter()
        done = fe.run()
        wall = time.perf_counter() - t0
        fe.close()
        assert len(done) == n and len(fe.pools) == len(cfgs)
        s = fe.stats
        csv_line(
            f"service_hetero_mix_{executor}_G{G}",
            wall / max(s.supersteps, 1) * 1e6,
            f"searches_per_sec={len(done) / wall:.2f} pools={len(fe.pools)} "
            f"supersteps={s.supersteps}")


def _policy_rows(G, p, budget, X):
    """SearchClient policy sweep over a heterogeneous 3-config load:
    throughput + the fairness p95 admission wait per policy, and the
    cross-pool fused-batch row for the gang policy."""
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfgs = (TreeConfig(X=X, F=6, D=8),
            TreeConfig(X=max(64, X // 2), F=6, D=6),
            TreeConfig(X=max(64, X // 4), F=6, D=5))
    n = 3 * G

    def build(policy, fuse=None):
        cl = SearchClient(env, BanditValueBackend(), G=G, p=p,
                          executor="faithful", policy=policy,
                          fuse_across_pools=fuse)
        handles = [cl.submit(SearchRequest(uid=i, seed=i, budget=budget,
                                           cfg=cfgs[i % len(cfgs)]))
                   for i in range(n)]
        return cl, handles

    for policy in POLICY_NAMES:
        cl, _ = build(policy)
        cl.drain()                       # warmup (jit compile)
        cl.close()
        cl, handles = build(policy)
        t0 = time.perf_counter()
        done = cl.drain()
        wall = time.perf_counter() - t0
        s = cl.stats
        assert len(done) == n and all(h.done() for h in handles)
        csv_line(
            f"service_policy_{policy.replace('-', '_')}_G{G}",
            wall / max(s.ticks, 1) * 1e6,
            f"searches_per_sec={n / wall:.2f} ticks={s.ticks} "
            f"supersteps={s.supersteps} "
            f"p95_wait_supersteps={s.wait_percentile(95)} "
            f"xpool_batches={cl.core.xpool_batches}")
        cl.close()

    # cross-pool fusion: the gang policy with ONE evaluate() across all
    # pools per tick vs the same gang schedule evaluated per pool
    per_mode = {}
    for fuse in (False, True):
        cl, _ = build("weighted-queue-depth", fuse=fuse)
        cl.drain()                       # warmup
        cl.close()
        cl, _ = build("weighted-queue-depth", fuse=fuse)
        t0 = time.perf_counter()
        cl.drain()
        wall = time.perf_counter() - t0
        per_mode[fuse] = (wall, cl.core, cl.stats)
        cl.close()
    wall_split, _, s_split = per_mode[False]
    wall_fused, core, s = per_mode[True]
    assert core.xpool_rows_max > core.xpool_pool_rows_max, (
        "fused cross-pool batches must be strictly larger than the best "
        "single-pool batch at heterogeneous load")
    csv_line(
        f"service_xpool_fuse_faithful_G{G}",
        wall_fused / max(s.ticks, 1) * 1e6,
        f"fused_rows_max={core.xpool_rows_max} "
        f"best_pool_rows={core.xpool_pool_rows_max} "
        f"batch_gain={core.xpool_rows_max / max(core.xpool_pool_rows_max, 1):.2f}x "
        f"xpool_batches={core.xpool_batches} "
        f"per_pool_wall_s={wall_split:.3f} fused_wall_s={wall_fused:.3f} "
        f"speedup={wall_split / max(wall_fused, 1e-9):.2f}x")


def _dispatch_k_rows(executors, G, p, budget, X, ks, reps: int = 3):
    """Fused K-superstep device dispatch (repro.core.fused): the same
    refill workload as the full-occupancy rows, swept over
    supersteps_per_dispatch.  K=1 is the classic phase-by-phase path;
    K>1 runs up to K supersteps per compiled lax.while_loop program,
    escaping only at move commits or host-bound expansions.  The
    speedup_vs_k1 field is the acceptance gate for ROADMAP item 2 —
    the per-superstep dispatch overhead the fusion removes."""
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    # ONE backend instance across warmup + measurement: the fused
    # program cache keys env/sim by identity, so a fresh backend per
    # build would recompile inside the timed run
    sim = BanditValueBackend()
    cfg = TreeConfig(X=X, F=6, D=8)
    n = 2 * G
    for executor in executors:
        base_us = None
        for K in ks:
            def build():
                svc = SearchService(cfg, env, sim, G=G,
                                    p=p, executor=executor,
                                    supersteps_per_dispatch=K)
                for i in range(n):
                    svc.submit(SearchRequest(uid=i, seed=i, budget=budget))
                return svc
            build().run()            # warmup (jit compile, per-K program)
            wall = float("inf")      # min-of-reps: dispatch overhead is
            for _ in range(reps):    # exactly what noise drowns
                svc = build()
                t0 = time.perf_counter()
                done = svc.run()
                wall = min(wall, time.perf_counter() - t0)
            assert len(done) == n
            s = svc.stats
            us = wall / max(s.supersteps, 1) * 1e6
            if K == 1:
                base_us = us
            csv_line(
                f"service_dispatch_k_{executor}_K{K}_G{G}", us,
                f"searches_per_sec={len(done) / wall:.2f} "
                f"supersteps={s.supersteps} "
                f"fused_dispatches={s.fused_dispatches} "
                f"ran_k={s.fused_ran_k} commit={s.fused_escape_commit} "
                f"expand={s.fused_escape_expand} "
                f"speedup_vs_k1={base_us / max(us, 1e-9):.2f}x")


def _shard_rows(G, p, budget, X, ds, reps: int = 3):
    """D-sharded serving: the refill workload at fixed G, swept over the
    shard count.  Each D partitions the same G slots into D per-device
    arenas (committed via launch.mesh.serving_devices — wraps on hosts
    with fewer devices), admission goes least-loaded, and fused
    dispatches run one compiled program per shard.  speedup_vs_d1 is the
    cross-device scaling signal; results are bit-identical at any D, so
    the row only moves wall clock."""
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    sim = BanditValueBackend()       # one instance: fused cache by identity
    cfg = TreeConfig(X=X, F=6, D=8)
    n = 2 * G
    base_sps = None
    for D in ds:
        def build():
            cl = SearchClient(env, sim, G=G, p=p, executor="faithful",
                              default_cfg=cfg, n_shards=D,
                              supersteps_per_dispatch=4)
            for i in range(n):
                cl.submit(SearchRequest(uid=i, seed=i, budget=budget))
            return cl
        build().drain()              # warmup (per-shard-count programs)
        wall = float("inf")
        for _ in range(reps):
            cl = build()
            t0 = time.perf_counter()
            done = cl.drain()
            wall = min(wall, time.perf_counter() - t0)
            s = cl.stats
            cl.close()
        assert len(done) == n
        sps = n / wall
        if base_sps is None:
            base_sps = sps
        csv_line(
            f"service_shard_D{D}_G{G}",
            wall / max(s.supersteps, 1) * 1e6,
            f"searches_per_sec={sps:.2f} shards={D} "
            f"supersteps={s.supersteps} "
            f"fused_dispatches={s.fused_dispatches} "
            f"speedup_vs_d1={sps / max(base_sps, 1e-9):.2f}x")


class HeavyHostEnv(BanditTreeEnv):
    """Latency-bound host environment for the overlap rows: every scalar
    transition pays a fixed service latency (modelling an RPC / external
    simulator call — the regime where host Expansion dominates the
    superstep and the paper overlaps it with the accelerator's in-tree
    phases).  The latency is sleep, not spin: a worker waiting on its
    environment yields the CPU, which is exactly what the gang pipeline
    hides — and the only thing it CAN hide on a single-core host.  The
    vectorized twin amortizes the same per-row bill 8x (one batched
    service call), so the vector-expansion rows are heavy too but leave
    nothing in a worker process to overlap.  Module-level and attribute-
    only, so the process-pool workers can unpickle their replicas."""

    VEC_AMORTIZE = 8.0

    def __init__(self, fanout=6, terminal_depth=12, latency_us=300.0):
        super().__init__(fanout=fanout, terminal_depth=terminal_depth)
        self.latency_us = float(latency_us)

    def step(self, state, action):
        time.sleep(self.latency_us * 1e-6)
        return super().step(state, action)

    def step_batch(self, states, actions):
        time.sleep(self.latency_us * 1e-6 * len(actions) / self.VEC_AMORTIZE)
        return super().step_batch(states, actions)


class HeavySimBackend(BanditValueBackend):
    """Latency-bound simulation backend for the overlap rows: evaluate()
    pays a per-batch service latency amortized SIM_AMORTIZE-fold across
    its rows (modelling a batched NN-inference / rollout-service call on
    the scheduler thread).  The values stay BanditValueBackend's pure
    per-state hash, so results remain batch-composition invariant and
    bit-identical across serving modes.  In the gang pipeline one gang's
    evaluate() is exactly the window the OTHER gang's posted env batch
    waits out in the worker processes — both latencies are sleeps, so on
    a single core they genuinely co-run."""

    SIM_AMORTIZE = 8.0

    def __init__(self, latency_us=300.0):
        self.latency_us = float(latency_us)

    def evaluate(self, states):
        time.sleep(self.latency_us * 1e-6 * len(states) / self.SIM_AMORTIZE)
        return super().evaluate(states)


def _overlap_rows(executors, G, p, budget, X, gangs, latency_us,
                  sim_latency_us, reps: int = 3):
    """Pipelined supersteps (overlap mode) vs lock-step on the heavy env:
    gangs x {faithful, pallas} x {vector, pool} expansion.  The pool rows
    are the headline — submit_batch posts the gang's env batch to the
    worker processes and the pipeline runs the OTHER gang's device
    phases + simulation while those workers wait out their transition
    latency.  The vector rows pay the same heavy bill eagerly on the
    scheduler thread (no async leg), so their speedup ~1.0 documents
    that the win comes from overlap, not from the mode flag.  Lock-step
    baselines are emitted as service_overlap_lockstep_* rows;
    speedup_vs_lockstep on the gang rows is the ROADMAP item 3 /
    acceptance gate (>= 1.3x on the G=16 pool leg; CI smoke gates the
    pool rows at >= 1.0x)."""
    env = HeavyHostEnv(fanout=6, terminal_depth=12, latency_us=latency_us)
    sim = HeavySimBackend(sim_latency_us)  # one instance: fused by identity
    cfg = TreeConfig(X=X, F=6, D=8)
    n = 2 * G

    def _measure(executor, expansion, overlap, n_gangs):
        # pool_workers=p: latency-bound workers are sleep-dominated, so
        # oversubscribing the core count is the right sizing (each worker
        # serializes its chunk's latencies; more workers = more in flight)
        cl = SearchClient(env, sim, G=G, p=p, executor=executor,
                          default_cfg=cfg, expansion=expansion,
                          pool_workers=p, overlap=overlap, n_gangs=n_gangs)
        try:
            # warmup on the SAME client: spawns the expansion pool's
            # worker processes and compiles the jit programs, so the
            # timed drain measures the pipeline, not process start-up
            for i in range(G):
                cl.submit(SearchRequest(uid=10_000 + i, seed=i, budget=1))
            cl.drain()
            best = float("inf")
            for r in range(reps):
                handles = [cl.submit(SearchRequest(uid=r * n + i, seed=i,
                                                   budget=budget))
                           for i in range(n)]
                s0 = cl.stats.supersteps
                t0 = time.perf_counter()
                cl.drain()
                wall = time.perf_counter() - t0
                assert all(h.done() for h in handles)
                best = min(best, wall)
                sups = cl.stats.supersteps - s0
        finally:
            cl.close()
        return best, sups

    for executor in executors:
        for expansion in ("vector", "pool"):
            base_wall, base_sups = _measure(executor, expansion, False, 1)
            csv_line(
                f"service_overlap_lockstep_{executor}_{expansion}_G{G}",
                base_wall / max(base_sups, 1) * 1e6,
                f"searches_per_sec={n / base_wall:.2f} "
                f"supersteps={base_sups} latency_us={latency_us:g} "
                f"sim_latency_us={sim_latency_us:g}")
            for n_gangs in gangs:
                wall, sups = _measure(executor, expansion, True, n_gangs)
                csv_line(
                    f"service_overlap_{executor}_{expansion}"
                    f"_gangs{n_gangs}_G{G}",
                    wall / max(sups, 1) * 1e6,
                    f"searches_per_sec={n / wall:.2f} supersteps={sups} "
                    f"latency_us={latency_us:g} sim_latency_us={sim_latency_us:g} "
                    f"speedup_vs_lockstep={base_wall / max(wall, 1e-9):.2f}x")


def _obs_rows(G, p, budget, X, reps: int = 3):
    """Observability overhead, two gates:

      * enabled  — the weighted-queue-depth 3-config workload run with
        tracing + metrics live (device-fence spans included) vs off;
        `enabled_overhead` is the min-of-reps end-to-end wall ratio and
        must stay < 1.05 at the full G=16 row;
      * disabled — the no-op instrumentation sequence a superstep pays
        when obs is off (NULL_TRACER spans + null-metric bumps), measured
        directly and expressed as a fraction of the disabled-path
        superstep time.  Noise-free, so CI gates `disabled_overhead`
        at < 1.02 (the ~0% claim).
    """
    from repro.obs import NULL_REGISTRY, NULL_TRACER

    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfgs = (TreeConfig(X=X, F=6, D=8),
            TreeConfig(X=max(64, X // 2), F=6, D=6),
            TreeConfig(X=max(64, X // 4), F=6, D=5))
    n = 3 * G

    def build(obs: bool):
        cl = SearchClient(env, BanditValueBackend(), G=G, p=p,
                          executor="faithful",
                          policy="weighted-queue-depth",
                          compact_threshold=0.5,
                          trace=obs, metrics=obs)
        for i in range(n):
            cl.submit(SearchRequest(uid=i, seed=i, budget=budget,
                                    cfg=cfgs[i % len(cfgs)]))
        return cl

    walls, steps, last = {}, {}, {}
    for obs in (False, True):
        build(obs).drain()               # warmup (jit compile)
        best = float("inf")
        for _ in range(reps):
            cl = build(obs)
            t0 = time.perf_counter()
            done = cl.drain()
            best = min(best, time.perf_counter() - t0)
            assert len(done) == n
            steps[obs], last[obs] = cl.stats.supersteps, cl
            cl.close()
        walls[obs] = best
    us_off = walls[False] / max(steps[False], 1) * 1e6
    us_on = walls[True] / max(steps[True], 1) * 1e6

    # the disabled path's entire per-superstep obs cost, measured alone:
    # the no-op span/instant/metric calls the wired layers make each
    # superstep (pool + engine + scheduler), against shared NULL objects
    null_metric = NULL_REGISTRY.counter("bench_noop")
    M = 20_000
    t0 = time.perf_counter()
    for _ in range(M):
        tok = NULL_TRACER.begin("superstep", cat="phase", tid=0, tick=0)
        with NULL_TRACER.span("select", cat="phase", tid=0, slots=1):
            pass
        with NULL_TRACER.span("expand", cat="phase", tid=0, slots=1,
                              mode="loop"):
            pass
        with NULL_TRACER.span("simulate", cat="phase", tid=0, rows=8):
            pass
        with NULL_TRACER.span("backup", cat="phase", tid=0, slots=1):
            pass
        NULL_TRACER.instant("move-commit", cat="request", tid=0, uid=0)
        null_metric.set(0)
        null_metric.set(1)
        null_metric.inc()
        null_metric.inc()
        null_metric.observe(8)
        null_metric.inc()
        NULL_TRACER.end(tok)
    noop_us = (time.perf_counter() - t0) / M * 1e6

    tracer = last[True].tracer
    csv_line(
        f"service_obs_overhead_G{G}", us_on,
        f"disabled_us={us_off:.1f} enabled_us={us_on:.1f} "
        f"enabled_overhead={walls[True] / max(walls[False], 1e-9):.3f}x "
        f"noop_us={noop_us:.3f} "
        f"disabled_overhead={1.0 + noop_us / max(us_off, 1e-9):.4f}x "
        f"trace_events={len(tracer.events())} dropped={tracer.dropped}")


def _nn_backend_rows(G, p, mbs=(1, 16, 128), lm_pools=(1, 8),
                     budget: int = 2, reps: int = 3):
    """The served NN simulation path (repro.sim), paper Fig. 5's
    batching claim made measurable end to end:

      * service_nn_backend_gomoku_mb<N>_G<g> — Gomoku policy-value
        self-play through SearchClient with SimServer(max_batch=N).
        mb=1 is per-row batch-1 inference (the paper's per-worker
        baseline); the speedup_vs_mb1 field on larger windows is the
        CI gate (>= 1.5x at the widest window).
      * service_nn_backend_gomoku_cache_{off,on}_G<g> — the same
        schedule replayed twice on one client, cache off vs a warm
        CachedSimBackend (second pass ~all hits; on must be >= off —
        bit-identity of the two is pinned in tests, only throughput is
        measured here).
      * service_nn_backend_lm_mb<pool> — the LM-decode workload:
        LMContinuationBackend's ContinuousBatcher pool size is the LM
        microbatch; the sweep records batched-decode scaling on the
        smoke model.  No gate: this workload is expansion-bound (the
        env's top_actions runs a full forward per expanded node,
        outside the sim backend), so pooling only moves the simulation
        slice — the row exists to track that slice commit to commit.
    """
    import jax

    from repro.envs import GomokuEnv
    from repro.envs.policy_net import NNSimBackend, init_params
    from repro.sim import CachedSimBackend, SimServer

    env = GomokuEnv()
    # 64-channel net: heavy enough that inference (not host tree work)
    # dominates the simulation phase the microbatch window sweeps
    params = init_params(jax.random.PRNGKey(0), channels=64)
    cfg = TreeConfig(X=192, F=36, D=5, beta=5.0, score_fn="puct",
                     leaf_mode="unexpanded", expand_all=True)
    n = 2 * G

    def build(sim):
        # vector expansion keeps the host env.step share small so the
        # row measures the inference path it sweeps
        cl = SearchClient(env, sim_backend=sim, G=G, p=p,
                          executor="faithful", default_cfg=cfg,
                          alternating_signs=True, expansion="vector")
        for i in range(n):
            cl.submit(SearchRequest(uid=i, seed=i, budget=budget))
        return cl

    def measure(mk_sim):
        build(mk_sim()).drain()          # warmup (jit compile)
        best = float("inf")
        for _ in range(reps):
            cl = build(mk_sim())
            t0 = time.perf_counter()
            done = cl.drain()
            best = min(best, time.perf_counter() - t0)
            assert len(done) == n
            cl.close()
        return best

    walls = {}
    for mb in mbs:
        walls[mb] = measure(
            lambda: SimServer(NNSimBackend(env, params), max_batch=mb))
        derived = f"searches_per_sec={n / walls[mb]:.2f} max_batch={mb}"
        if mb != mbs[0]:
            derived += (f" speedup_vs_mb{mbs[0]}="
                        f"{walls[mbs[0]] / max(walls[mb], 1e-9):.2f}x")
        csv_line(f"service_nn_backend_gomoku_mb{mb}_G{G}",
                 walls[mb] * 1e6, derived)

    # cache off vs on: per-rep, pass 1 populates (identical schedule ->
    # pass 2 is ~all transpositions), pass 2 is the measured row
    def second_pass(cache: bool):
        best = float("inf")
        # >= 3 iterations even in smoke: CI gates warm-cache >= cache-off
        # strictly, so these two rows get min-of-N noise suppression
        for _ in range(1 + max(reps, 2)):
            sim = SimServer(NNSimBackend(env, params), max_batch=mbs[-1])
            if cache:
                sim = CachedSimBackend(sim, capacity=8192)
            cl = build(sim)
            cl.drain()                   # pass 1: cold (populates cache)
            for i in range(n):
                cl.submit(SearchRequest(uid=n + i, seed=i, budget=budget))
            t0 = time.perf_counter()
            done = cl.drain()            # pass 2: warm (drain is cumulative)
            best = min(best, time.perf_counter() - t0)
            assert len(done) == 2 * n
            cl.close()
        return best

    cold = second_pass(False)
    csv_line(f"service_nn_backend_gomoku_cache_off_G{G}", cold * 1e6,
             f"searches_per_sec={n / cold:.2f}")
    warm = second_pass(True)
    csv_line(f"service_nn_backend_gomoku_cache_on_G{G}", warm * 1e6,
             f"searches_per_sec={n / warm:.2f} "
             f"cache_speedup={cold / max(warm, 1e-9):.2f}x")

    # LM decode-as-search: ContinuousBatcher pool size = LM microbatch
    from repro import configs
    from repro.models import lm as lm_model
    from repro.sim import LMContinuationBackend, LMTreeEnv

    lm_cfg = configs.get_config("llama3.2-1b", smoke=True)
    lm_params = lm_model.init_params(lm_cfg, jax.random.PRNGKey(0))
    # long horizon: the continuation decode (what the pool batches) has
    # to be a visible slice of the superstep
    lm_env = LMTreeEnv(lm_cfg, lm_params, fanout=4, horizon=12)
    lm_tree = TreeConfig(X=64, F=4, D=4)
    lm_n, lm_G, lm_p = 2, 2, 8

    lm_walls = {}
    for pool in lm_pools:
        def lm_build():
            sim = SimServer(LMContinuationBackend(lm_env, pool_size=pool),
                            max_batch=lm_G * lm_p,
                            default_priority="interactive")
            cl = SearchClient(lm_env, sim_backend=sim, G=lm_G, p=lm_p,
                              executor="faithful", default_cfg=lm_tree)
            for i in range(lm_n):
                cl.submit(SearchRequest(uid=i, seed=i, budget=2))
            return cl

        lm_build().drain()               # warmup (jit compile)
        best = float("inf")
        for _ in range(reps):
            cl = lm_build()
            t0 = time.perf_counter()
            done = cl.drain()
            best = min(best, time.perf_counter() - t0)
            assert len(done) == lm_n
            cl.close()
        lm_walls[pool] = best
        derived = (f"searches_per_sec={lm_n / best:.2f} pool_size={pool}")
        if pool != lm_pools[0]:
            derived += (f" speedup_vs_pool{lm_pools[0]}="
                        f"{lm_walls[lm_pools[0]] / max(best, 1e-9):.2f}x")
        csv_line(f"service_nn_backend_lm_mb{pool}", best * 1e6, derived)


def run(smoke: bool = False):
    executors = ("reference", "faithful", "pallas")
    gs = (2,) if smoke else (1, 2, 4, 8)
    p, budget, X = (4, 2, 64) if smoke else (8, 8, 512)
    for executor in executors:
        for G in gs:
            _one(executor, G, p=p, budget=budget, X=X)
    # low occupancy (G//4 active slots): masked vs compacted execution
    G = 2 if smoke else 8
    for executor in executors:
        for tag, thresh in (("low_masked", 0.0), ("low_compacted", 0.5)):
            _one(executor, G, p=p, budget=budget, X=X,
                 n_req=max(1, G // 4), compact_threshold=thresh, tag=tag)

    # compaction sessions: per-superstep gather/scatter vs one resident
    # sub-arena (scatter deferred to close) on a stable low-occupancy set
    _persist_compact_rows(("faithful",) if smoke else executors,
                          G, p, budget, X)

    # heterogeneous-config mix through the multi-arena frontend
    _hetero_rows(("faithful",) if smoke else executors,
                 2 if smoke else 4, p, budget, X)

    # SearchClient schedule policies + the cross-pool fused evaluate
    _policy_rows(2 if smoke else 4, p, budget, X)

    # fused K-superstep device dispatch: supersteps per compiled program
    # (ROADMAP item 2 acceptance — K>1 must beat K=1 end to end).  X is
    # pinned to the dispatch-bound regime: the sweep measures host
    # round-trip amortization, which X=512 XLA kernel time drowns.
    _dispatch_k_rows(("faithful", "pallas"), 2 if smoke else 16, p,
                     budget=4 if smoke else budget,
                     X=X if smoke else 128,
                     ks=(1, 4) if smoke else (1, 2, 4, 8))

    # D-sharded serving: searches/sec vs shard count at fixed G (under
    # XLA_FLAGS=--xla_force_host_platform_device_count=4 each shard gets
    # its own device; a 1-device host still measures partition overhead)
    _shard_rows(4 if smoke else 16, p, budget=4 if smoke else budget,
                X=X if smoke else 128, ds=(1, 2) if smoke else (1, 2, 4))

    # pipelined supersteps (overlap mode): double-buffered gangs vs
    # lock-step on the heavy latency-bound env — ROADMAP item 3
    # acceptance (>= 1.3x on the G=16 pool-expansion leg)
    _overlap_rows(("faithful",) if smoke else ("faithful", "pallas"),
                  G=8 if smoke else 16, p=p,
                  budget=2 if smoke else 6,
                  X=X if smoke else 128,
                  gangs=(2,) if smoke else (2, 4),
                  latency_us=3500.0, sim_latency_us=2500.0,
                  reps=1 if smoke else 3)

    # observability overhead: tracing+metrics enabled vs off, plus the
    # disabled no-op path measured directly (the CI-gated ~0% claim)
    _obs_rows(4 if smoke else 16, p, budget, X)

    # served NN simulation (repro.sim): microbatch window sweep on the
    # Gomoku policy net + transposition-cache replay + LM decode pool
    # sweep.  G/p are pinned (16/16) even in smoke — the >= 1.5x
    # batched-vs-batch-1 CI gate needs enough concurrent rows per
    # superstep for the admission window to matter.
    _nn_backend_rows(16, 16, reps=1 if smoke else 3)

    # host-expansion engine at high G: per-slot env.step loop vs ONE
    # flattened step_batch over all slots (core.expand) — the ROADMAP
    # "host expansion is the next hot spot once G*p grows" row.  The
    # speedup row compares the expansion phase itself (stats.t_expand).
    G = 4 if smoke else 16
    per_mode = {}
    for expansion in ("loop", "vector"):
        stats = _one("faithful", G, p=p, budget=budget, X=X,
                     expansion=expansion, tag=f"expand_{expansion}")
        per_mode[expansion] = (
            stats.t_expand / max(stats.supersteps, 1) * 1e6)
    lo, ve = per_mode["loop"], per_mode["vector"]
    csv_line(f"service_expand_speedup_G{G}", ve,
             f"loop_us_per_superstep={lo:.1f} "
             f"vector_us_per_superstep={ve:.1f} "
             f"expansion_speedup={lo / max(ve, 1e-9):.2f}x")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
