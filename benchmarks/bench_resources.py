"""Table I analogue: accelerator memory for the UCT at the paper's full
benchmark scales, against the TPU VMEM budget (the paper reports FPGA
SRAM: 24 MB / 69% for Pong, 16 MB / 46% for Gomoku on a U200)."""

from __future__ import annotations

from benchmarks.common import csv_line
from repro.configs.gomoku_cfg import TREE as GOMOKU
from repro.configs.pong import TREE as PONG
from repro.envs import GomokuEnv, PongLiteEnv

VMEM_BUDGET = 128 * 1024 * 1024  # v5e VMEM per core


def run():
    rows = []
    for name, cfg, env in (("pong", PONG, PongLiteEnv()),
                           ("gomoku", GOMOKU, GomokuEnv())):
        b = cfg.sram_bytes()
        frac = b["total_bytes"] / VMEM_BUDGET
        csv_line(f"table1_uct_bytes_{name}", b["total_bytes"] / 1e6,
                 f"MB={b['total_bytes']/2**20:.1f};vmem_frac={frac:.2%};"
                 f"edge_MB={b['edge_bytes']/2**20:.1f}")
        st_bytes = env.state_shape[0] * 4
        csv_line(f"table1_st_bytes_per_state_{name}", st_bytes,
                 f"host_table_MB={st_bytes*cfg.X/2**20:.1f}")
        rows.append((name, b, st_bytes))
    return rows


if __name__ == "__main__":
    run()
