"""Shared benchmark helpers."""

from __future__ import annotations

import time

import numpy as np

from repro.core import TreeConfig, TreeParallelMCTS


class NullSim:
    """Zero-cost simulation backend: isolates in-tree operation latency
    (paper Fig. 4 measures Selection/Expansion/BackUp without Simulation)."""

    def __init__(self, value=0.1):
        self.value = value

    def evaluate(self, states):
        return np.full(len(states), self.value, np.float32), None


def run_supersteps(cfg, env, sim, p, executor, n, seed=0, alternating=False):
    m = TreeParallelMCTS(cfg, env, sim, p=p, executor=executor,
                         alternating_signs=alternating, seed=seed)
    m.superstep()          # warmup (jit compile)
    m.reset(seed)
    t0 = time.perf_counter()
    for _ in range(n):
        m.superstep()
    wall = time.perf_counter() - t0
    return m.stats, wall


# rows collected since the last drain; run.py snapshots them per bench
# module into BENCH_<name>.json so the perf trajectory is recorded
_RESULTS: list[dict] = []


def csv_line(name, us_per_call, derived=""):
    print(f"{name},{us_per_call:.2f},{derived}")
    _RESULTS.append(
        {"name": name, "us_per_call": round(us_per_call, 2),
         "derived": derived})


def drain_results() -> list[dict]:
    rows = list(_RESULTS)
    _RESULTS.clear()
    return rows
