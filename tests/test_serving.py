"""Continuous-batching serving layer: ragged per-slot decode must equal
independent per-sequence decoding, under staggered admission/eviction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm, steps
from repro.serving import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def greedy_reference(cfg, params, prompt, n_new):
    """Single-sequence greedy decode (B=1, synchronized path)."""
    caches = lm.init_caches(cfg, 1, max_seq=64)
    pre = steps.make_prefill_step(cfg, impl="naive")
    dec = steps.make_decode_step(cfg, impl="naive")
    lg, caches = pre(params, jnp.asarray(prompt, jnp.int32)[None], caches)
    out = [int(jnp.argmax(lg[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        tok = jnp.asarray([[out[-1]]], jnp.int32)
        lg, caches = dec(params, caches, tok, jnp.asarray(pos))
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    return out


def test_ragged_batching_matches_reference(model):
    cfg, params = model
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 3, 7)]
    n_new = 6

    b = ContinuousBatcher(cfg, params, pool_size=2, max_seq=64, impl="naive")
    for i, pr in enumerate(prompts):
        b.submit(Request(uid=i, prompt=pr, max_new_tokens=n_new))
    done = b.run(max_steps=200)
    assert len(done) == len(prompts)

    for req in done:
        ref = greedy_reference(cfg, params, req.prompt, n_new)
        assert req.tokens == ref, f"uid={req.uid}"


def test_pool_reuses_slots(model):
    cfg, params = model
    b = ContinuousBatcher(cfg, params, pool_size=1, max_seq=64, impl="naive")
    for i in range(3):
        b.submit(Request(uid=i, prompt=np.array([1, 2, 3], np.int32),
                         max_new_tokens=3))
    done = b.run(max_steps=100)
    assert [r.uid for r in done] == [0, 1, 2]
    # with one slot and identical prompts, outputs must be identical
    assert done[0].tokens == done[1].tokens == done[2].tokens
