"""Flash-attention Pallas kernel vs the pure-jnp oracle
(models.attention.naive_attention) across shape/dtype/mask sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import blockwise_attention, naive_attention


def make_qkv(B, Sq, Sk, H, Hkv, dh, dtype, seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), dtype)
    kk = jax.random.normal(ks[1], (B, Sk, Hkv, dh), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, dh), dtype)
    return q, kk, v


SHAPES = [
    # B, Sq, Sk, H, Hkv, dh
    (1, 128, 128, 2, 2, 32),
    (2, 256, 256, 4, 2, 64),
    (1, 200, 200, 2, 1, 16),    # non-multiple of block
    (2, 384, 384, 8, 8, 128),
]


# Triage note (was a 16-case xfail sweep since the seed commit): the
# whole sweep crashed with one genuine interpreter-mode kernel bug — a
# bare int leading index in pl.load, rejected by the interpret-mode
# load-discharge rule — not a tolerance problem.  With the load fixed
# (kernels/flash_attention.py) every case passes at the original
# tolerances (f32 max |err| ~8e-7 vs atol 2e-5, bf16 ~1.1e-2 vs 2e-2),
# so the sweep runs as a plain strict test again.
@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_matches_naive(shape, dtype, window):
    B, Sq, Sk, H, Hkv, dh = shape
    q, k, v = make_qkv(B, Sq, Sk, H, Hkv, dh, dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          blk_q=64, blk_k=64, interpret=True)
    ref = naive_attention(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [None, 96])
def test_blockwise_matches_naive(window):
    """The jnp blockwise path (used in the dry-run) against the oracle."""
    q, k, v = make_qkv(2, 320, 320, 4, 2, 32, jnp.float32)
    out = blockwise_attention(q, k, v, causal=True, window=window)
    ref = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_blockwise_prefix_mask():
    """paligemma bidirectional-prefix + causal-suffix mask."""
    q, k, v = make_qkv(1, 160, 160, 2, 1, 16, jnp.float32)
    out = blockwise_attention(q, k, v, causal=True, prefix=32)
    ref = naive_attention(q, k, v, causal=True, prefix=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
