"""Checkpointing: atomicity, retention, resume-equality, elastic restore,
async save."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.distributed import (
    CheckpointManager, latest_step, restore_checkpoint, save_checkpoint,
)


def tree_eq(a, b):
    return all(jax.tree.leaves(
        jax.tree.map(lambda x, y: bool(np.array_equal(np.asarray(x),
                                                      np.asarray(y))), a, b)))


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "scalar": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    state = make_state()
    save_checkpoint(tmp_path, 7, state, extra={"note": "x"})
    restored, manifest = restore_checkpoint(tmp_path, 7, state)
    assert tree_eq(state, restored)
    assert manifest["step"] == 7
    assert manifest["extra"]["note"] == "x"


def test_atomicity_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 1, make_state())
    assert not list(tmp_path.glob("*.tmp"))
    assert latest_step(tmp_path) == 1


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_state(s))
    assert latest_step(tmp_path) == 4
    kept = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert kept == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=3, async_save=True)
    st = make_state(5)
    mgr.save(10, st)
    mgr.wait()
    s, restored, _ = mgr.restore_latest(st)
    assert s == 10 and tree_eq(st, restored)


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoint saved under one device layout restores under another
    (here: default device -> explicit 1x1 mesh NamedSharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = make_state(9)
    save_checkpoint(tmp_path, 3, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda l: NamedSharding(mesh, P(*([None] * np.asarray(l).ndim))),
        state)
    restored, _ = restore_checkpoint(tmp_path, 3, state, shardings)
    assert tree_eq(state, restored)
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf.sharding, NamedSharding)


def test_train_resume_bit_equal(tmp_path):
    """Restart-replay determinism: train 6 steps straight vs 3 + resume 3 —
    identical parameters (checkpoint + deterministic data pipeline)."""
    from repro.launch.train import main as train_main

    a = train_main(["--arch", "llama3.2-1b", "--smoke", "--steps", "6",
                    "--batch", "2", "--seq", "16", "--log-every", "1"])
    train_main(["--arch", "llama3.2-1b", "--smoke", "--steps", "3",
                "--batch", "2", "--seq", "16", "--ckpt", str(tmp_path),
                "--ckpt-every", "2", "--log-every", "1"])
    b = train_main(["--arch", "llama3.2-1b", "--smoke", "--steps", "6",
                    "--batch", "2", "--seq", "16", "--ckpt", str(tmp_path),
                    "--ckpt-every", "100", "--log-every", "1"])
    assert abs(a[-1] - b[-1]) < 1e-4
