"""Arena-native Pallas path: bit-identical slot evolution vs the vmapped
jit arena and the sequential numpy oracle, across random occupancy masks
and the compacted low-occupancy execution path.

Three layers of evidence:
  1. raw executor protocol driven with random [G] active masks — every
     phase (selection / insert / finalize / backup) produces the same
     per-slot trees on reference / faithful / pallas, and inactive slots
     stay bit-frozen;
  2. SearchService(executor="pallas") end to end equals G independent
     single-tree runs of the numpy oracle (the acceptance claim);
  3. masked vs compacted execution are interchangeable: the same workload
     with compaction disabled and enabled returns identical results while
     the compacted run actually exercises gather_sub/scatter_sub.
"""

import numpy as np
import pytest

from repro.core import TreeConfig, TreeParallelMCTS, make_intree_executor
from repro.core.tree import NULL
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import SearchRequest, SearchService

CFG = TreeConfig(X=256, F=4, D=6)
CFG_ALL = TreeConfig(X=256, F=4, D=5, score_fn="puct",
                     leaf_mode="unexpanded", expand_all=True)
ENV = BanditTreeEnv(fanout=4, terminal_depth=10)
P = 6
G = 4

EXECUTORS = ("reference", "faithful", "pallas")


def _random_masks(steps, seed):
    rng = np.random.RandomState(seed)
    masks = []
    for _ in range(steps):
        m = rng.rand(G) < 0.6
        if not m.any():
            m[rng.randint(G)] = True
        masks.append(m)
    return masks


def _drive_raw(cfg, name, masks, values):
    """Drive the executor protocol without an env: insert every selected
    expansion, finalize it non-terminal with F actions, back up canned
    values.  Pure array flow — identical inputs for every executor."""
    ex = make_intree_executor(cfg, G, name)
    for g in range(G):
        ex.reset_slot(g, cfg.F)
    K = P * cfg.Fp if cfg.expand_all else P
    for step, active in enumerate(masks):
        sel_dev = ex.selection(active, P)
        sel = ex.sel_to_host(sel_dev)
        new_nodes = ex.insert(active, sel_dev)              # [G, P, Fp]
        fin_nodes = np.full((G, K), NULL, np.int32)
        fin_na = np.zeros((G, K), np.int32)
        fin_term = np.zeros((G, K), np.int32)
        sim_nodes = np.zeros((G, P), np.int32)
        for g in np.flatnonzero(active):
            ins = new_nodes[g].reshape(-1)
            ins = ins[ins != NULL][:K]
            fin_nodes[g, : len(ins)] = ins
            fin_na[g, : len(ins)] = cfg.F
            single = sel["expand_action"][g] >= 0
            sim_nodes[g] = np.where(single, new_nodes[g, :, 0],
                                    sel["leaves"][g])
        ex.finalize(fin_nodes, fin_na, fin_term,
                    np.full((G, P), NULL, np.int32),
                    np.zeros((G, P, cfg.Fp), np.int32))
        ex.backup(active, sel_dev, sim_nodes, values[step], False)
    return [ex.slot_snapshot(g) for g in range(G)]


@pytest.mark.parametrize("cfg", [CFG, CFG_ALL],
                         ids=["single-expand", "expand-all-puct"])
def test_executors_agree_under_random_masks(cfg):
    steps = 5
    masks = _random_masks(steps, seed=11)
    rng = np.random.RandomState(7)
    from repro.core import fixedpoint as fx
    values = np.asarray(
        fx.encode(rng.uniform(-1, 1, (steps, G, P)).astype(np.float32)),
        np.int32)
    snaps = {name: _drive_raw(cfg, name, masks, values)
             for name in EXECUTORS}
    for name in ("faithful", "pallas"):
        for g in range(G):
            for k in snaps["reference"][g]:
                np.testing.assert_array_equal(
                    snaps["reference"][g][k], snaps[name][g][k],
                    err_msg=f"{name} slot={g} field={k}")


def test_inactive_slots_bit_frozen_on_pallas():
    """A slot that is never activated must be untouched by the kernels."""
    masks = [np.array([True, False, True, False])] * 3
    rng = np.random.RandomState(3)
    from repro.core import fixedpoint as fx
    values = np.asarray(
        fx.encode(rng.uniform(-1, 1, (3, G, P)).astype(np.float32)),
        np.int32)
    snaps = _drive_raw(CFG, "pallas", masks, values)
    for g in (1, 3):
        assert int(snaps[g]["size"]) == 1
        assert snaps[g]["node_N"].sum() == 0
        assert snaps[g]["edge_N"].sum() == 0
        assert snaps[g]["edge_VL"].sum() == 0
        assert snaps[g]["node_O"].sum() == 0


def _single_tree_reference(seed, supersteps):
    m = TreeParallelMCTS(CFG, ENV, BanditValueBackend(), p=P,
                         executor="reference", seed=seed)
    for _ in range(supersteps):
        m.superstep()
    return m.exec.snapshot(m.tree), m.exec.best_action(m.tree)


def test_pallas_service_bit_identical_to_numpy_oracle():
    """Acceptance: SearchService(executor='pallas') end to end — every
    slot's tree evolution equals an independent single-tree run of the
    sequential numpy oracle, bit for bit."""
    budget = 5
    svc = SearchService(CFG, ENV, BanditValueBackend(), G=G, p=P,
                        executor="pallas")
    for i in range(G):
        svc.submit(SearchRequest(uid=i, seed=i, budget=budget,
                                 keep_tree=True))
    done = {r.uid: r for r in svc.run()}
    assert sorted(done) == list(range(G))
    for i in range(G):
        ref_snap, ref_action = _single_tree_reference(i, budget)
        snap = done[i].tree_snapshot
        for k in ref_snap:
            np.testing.assert_array_equal(ref_snap[k], snap[k],
                                          err_msg=f"uid={i} field={k}")
        assert done[i].actions == [ref_action]


@pytest.mark.parametrize("executor", ["faithful", "pallas"])
def test_compacted_equals_masked(executor):
    """Mixed budgets drain the arena unevenly, so occupancy decays and the
    threshold run compacts while the disabled run masks — results must be
    bit-identical and the compacted path must actually trigger."""
    def go(thresh):
        svc = SearchService(CFG, ENV, BanditValueBackend(), G=G, p=P,
                            executor=executor, compact_threshold=thresh)
        for i in range(3):
            svc.submit(SearchRequest(uid=i, seed=40 + i, budget=3 + 2 * i,
                                     keep_tree=True))
        return {r.uid: r for r in svc.run()}, svc.stats

    masked, s_masked = go(0.0)
    compacted, s_comp = go(0.5)
    assert s_masked.compacted_supersteps == 0
    assert s_comp.compacted_supersteps > 0
    assert sorted(masked) == sorted(compacted)
    for uid in masked:
        assert masked[uid].actions == compacted[uid].actions
        assert masked[uid].supersteps == compacted[uid].supersteps
        for k in masked[uid].tree_snapshot:
            np.testing.assert_array_equal(
                masked[uid].tree_snapshot[k],
                compacted[uid].tree_snapshot[k],
                err_msg=f"uid={uid} field={k}")
