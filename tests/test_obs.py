"""Observability layer: tracer semantics, metrics format, service wiring.

Claim groups:

  * tracer — per-track LIFO nesting is enforced (out-of-order end
    asserts), nested spans export with child intervals inside parents
    (pinned with an injectable fake clock), the ring drops oldest,
    async begin/end pairs carry their id through;
  * metrics — Prometheus exposition format (# HELP / # TYPE, label
    escaping, cumulative histogram buckets with the +Inf closer),
    get-or-create sharing, kind conflicts raise, the null registry is
    inert;
  * service wiring — a 3-bucket heterogeneous run under the
    weighted-queue-depth gang tick with compaction enabled exports
    valid Chrome-trace JSON covering all six superstep phases and the
    full request lifecycle (submit -> result and submit -> evict), and
    client.metrics() renders the scheduler/pool telemetry.
"""

import json

import pytest

from repro.core import TreeConfig
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.obs import (
    NULL_REGISTRY, NULL_TRACER, MetricsRegistry, Tracer,
)
from repro.service import SearchClient, SearchRequest

PHASES = ("select", "expand", "simulate", "backup",
          "compact-gather", "compact-scatter")


def _fake_clock(step_ns: int = 1000):
    t = [0]

    def clk():
        t[0] += step_ns
        return t[0]
    return clk


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_nested_spans_export_child_inside_parent():
    tr = Tracer(clock_ns=_fake_clock())
    tid = tr.track("main")
    with tr.span("outer", cat="phase", tid=tid):
        with tr.span("inner", cat="phase", tid=tid, rows=3):
            pass
    ev = tr.events()
    # inner closes first, so it is recorded first
    assert [e["name"] for e in ev] == ["inner", "outer"]
    inner, outer = ev
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["args"] == {"rows": 3}
    assert all(e["ph"] == "X" for e in ev)
    assert tr.open_depth(tid) == 0


def test_out_of_order_end_asserts():
    tr = Tracer()
    a = tr.begin("a")
    tr.begin("b")
    with pytest.raises(AssertionError):
        tr.end(a)


def test_tracks_are_independent_stacks():
    tr = Tracer(clock_ns=_fake_clock())
    t0, t1 = tr.track("sched"), tr.track("pool")
    assert t0 != t1
    a = tr.begin("tick", tid=t0)
    b = tr.begin("superstep", tid=t1)
    tr.end(a)          # legal: different track than b
    tr.end(b)
    assert [e["tid"] for e in tr.events()] == [t0, t1]


def test_ring_drops_oldest():
    tr = Tracer(capacity=4, clock_ns=_fake_clock())
    for i in range(10):
        tr.instant(f"i{i}")
    ev = tr.events()
    assert [e["name"] for e in ev] == ["i6", "i7", "i8", "i9"]
    assert tr.dropped == 6
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_async_pairs_and_export_schema(tmp_path):
    tr = Tracer(clock_ns=_fake_clock())
    tr.track("main")
    tr.async_begin("request", 7, cat="request", uid=7)
    tr.instant("admit", cat="request", uid=7)
    tr.async_end("request", 7, cat="request", status="done")
    path = tmp_path / "trace.json"
    out = tr.export(str(path))
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(out))
    evs = loaded["traceEvents"]
    # metadata first: process + thread naming for the viewer
    assert evs[0]["ph"] == "M" and evs[0]["name"] == "process_name"
    pair = [e for e in evs if e["ph"] in "be"]
    assert [e["ph"] for e in pair] == ["b", "e"]
    assert all(e["id"] == 7 for e in pair)
    assert all("ts" in e and "pid" in e and "tid" in e for e in pair)


def test_export_coerces_exotic_arg_values(tmp_path):
    import numpy as np
    tr = Tracer(clock_ns=_fake_clock())
    tr.instant("x", rows=np.int32(5), frac=np.float64(0.5), tag=object())
    out = tr.export()
    json.dumps(out)    # must not raise
    args = out["traceEvents"][-1]["args"]
    assert args["rows"] == 5 and args["frac"] == 0.5
    assert isinstance(args["tag"], str)


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    tok = NULL_TRACER.begin("x")
    NULL_TRACER.end(tok)
    with NULL_TRACER.span("y"):
        pass
    NULL_TRACER.instant("z")
    assert NULL_TRACER.events() == []
    assert NULL_TRACER.export() == {"traceEvents": [],
                                    "displayTimeUnit": "ms"}


def test_tracer_rejects_bad_capacity():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_prometheus_render_format():
    reg = MetricsRegistry()
    c = reg.counter("foo_total", "things done", bucket="a")
    c.inc()
    c.inc(2)
    g = reg.gauge("bar")
    g.set(5)
    g.dec()
    text = reg.render()
    lines = text.splitlines()
    assert "# HELP foo_total things done" in lines
    assert "# TYPE foo_total counter" in lines
    assert 'foo_total{bucket="a"} 3' in lines
    assert "# TYPE bar gauge" in lines
    assert "bar 4" in lines
    # get-or-create: same (name, labels) -> same series
    assert reg.counter("foo_total", bucket="a") is c
    assert reg.get("foo_total", bucket="a").value == 3


def test_histogram_buckets_are_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1, 2, 4))
    for v in (1, 3, 9):
        h.observe(v)
    lines = reg.render().splitlines()
    assert 'lat_bucket{le="1"} 1' in lines
    assert 'lat_bucket{le="2"} 1' in lines
    assert 'lat_bucket{le="4"} 2' in lines
    assert 'lat_bucket{le="+Inf"} 3' in lines
    assert "lat_sum 13" in lines
    assert "lat_count 3" in lines
    snap = reg.snapshot()
    assert snap["lat"]['lat_bucket{le="+Inf"}'] == 3


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")


def test_label_values_are_escaped():
    reg = MetricsRegistry()
    reg.counter("esc_total", tag='a"b\\c\nd').inc()
    line = [ln for ln in reg.render().splitlines()
            if ln.startswith("esc_total")][0]
    assert line == 'esc_total{tag="a\\"b\\\\c\\nd"} 1'


def test_null_registry_is_inert():
    assert not NULL_REGISTRY.enabled
    m = NULL_REGISTRY.counter("anything", bucket="x")
    m.inc()
    m.observe(3)
    m.set(1)
    assert NULL_REGISTRY.render() == ""
    assert NULL_REGISTRY.snapshot() == {}
    assert NULL_REGISTRY.get("anything") is None


# ---------------------------------------------------------------------------
# service wiring: 3 heterogeneous buckets, all phases + full lifecycle
# ---------------------------------------------------------------------------

def test_three_bucket_run_exports_phases_and_lifecycle():
    env = BanditTreeEnv(fanout=3, terminal_depth=12)
    cfgs = [TreeConfig(X=96, F=3, D=5), TreeConfig(X=64, F=3, D=4),
            TreeConfig(X=48, F=3, D=6)]
    cl = SearchClient(
        env, BanditValueBackend(), G=4, p=4, default_cfg=cfgs[0],
        policy="weighted-queue-depth", compact_threshold=0.7,
        trace=True, metrics=True)
    for i in range(6):
        cl.submit(SearchRequest(uid=i, seed=i, budget=3, moves=2,
                                cfg=cfgs[i % 3]))
    doomed = cl.submit(SearchRequest(uid=99, seed=7, budget=64),
                       deadline_supersteps=0)
    cl.drain()
    assert doomed.status() == "evicted"

    trace = cl.trace_export()
    json.dumps(trace)                      # valid Chrome-trace JSON
    evs = trace["traceEvents"]
    names = {e["name"] for e in evs}
    for phase in PHASES:
        assert phase in names, f"phase {phase!r} missing from trace"
    # request lifecycle: async b/e pairs for a completed and an evicted
    # request, with the connecting instants in between
    begun = {e["id"] for e in evs if e.get("ph") == "b"}
    ended = {e["id"]: e for e in evs if e.get("ph") == "e"}
    assert 0 in begun and ended[0]["args"]["status"] == "done"
    assert 99 in begun and ended[99]["args"]["status"] == "evicted"
    assert {"submit", "admit", "move-commit", "evict"} <= names
    # every pool got its own named track, plus the scheduler's
    tracks = {e["args"]["name"] for e in evs
              if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert "scheduler" in tracks
    assert sum(t.startswith("pool:") for t in tracks) == 3

    text = cl.metrics()
    assert "service_supersteps_total" in text
    assert "service_smoothed_load" in text
    assert "service_admitted_total" in text
    assert 'reason="deadline"' in text
    snap = cl.registry.snapshot()
    assert any(k.startswith("service_queue_depth") for k in snap)
    cl.close()
