import os

# Tests must see the real single CPU device (the 512-device override is
# exclusively for launch/dryrun.py).
os.environ.pop("XLA_FLAGS", None)
