"""Roofline analyses: jaxpr cost walker and HLO collective scaling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import collectives as coll
from repro.launch import roofline


def test_jaxpr_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = roofline.jaxpr_costs(f, a, b)
    assert c["flops"] == 2 * 128 * 256 * 64


def test_jaxpr_scan_multiplier():
    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = roofline.jaxpr_costs(f, x)
    assert c["flops"] >= 7 * 2 * 64**3
    assert c["flops"] < 8 * 2 * 64**3


def test_jaxpr_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = roofline.jaxpr_costs(f, x)
    assert c["flops"] >= 15 * 2 * 32**3


def test_jaxpr_grad_includes_remat():
    def loss(w, x):
        h = jax.checkpoint(lambda a: jnp.tanh(a @ w))(x)
        return jnp.sum(h @ w)

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    base = roofline.jaxpr_costs(lambda w, x: loss(w, x), w, x)
    g = roofline.jaxpr_costs(lambda w, x: jax.grad(loss)(w, x), w, x)
    # grad + recompute must cost at least 2.5x the forward dots
    assert g["flops"] > 2.5 * base["flops"]


SYNTH_HLO = """\
HloModule test

%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[1024]{0} all-reduce(%x), channel_id=1
  ROOT %t = (s32[], f32[64]) tuple(%a, %b)
}

%cond.1 (p: (s32[], f32[64])) -> pred[] {
  ROOT %lt = pred[] compare(%a, %b), direction=LT
}

ENTRY %main (a: f32[64]) -> f32[64] {
  %ag = f32[2048]{0} all-gather(%a), channel_id=2
  %w = (s32[], f32[64]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %r = f32[64]{0} bitcast(%w)
}
"""


def test_collective_bytes_parse():
    c = coll.collective_bytes(SYNTH_HLO)
    assert c["all-reduce"] == 1024 * 4
    assert c["all-gather"] == 2048 * 4


def test_scaled_collectives_trip_counts():
    s = roofline.scaled_collectives(SYNTH_HLO)
    assert s["all-gather"] == 2048 * 4          # entry: x1
    assert s["all-reduce"] == 12 * 1024 * 4     # while body: x12
    assert s["unannotated_whiles"] == 0


def test_split_computations():
    comps = roofline._split_computations(SYNTH_HLO)
    assert set(comps) == {"body.1", "cond.1", "main"}


def test_jaxpr_vs_xla_cost_analysis_loop_free():
    """Cross-validation: on a loop-free model, the jaxpr walker and XLA's
    cost_analysis agree on FLOPs (within elementwise noise)."""
    def f(w1, w2, x):
        return jnp.sum(jax.nn.relu(x @ w1) @ w2)

    shapes = [jax.ShapeDtypeStruct(s, jnp.float32)
              for s in ((256, 512), (512, 128), (64, 256))]
    ours = roofline.jaxpr_costs(f, *shapes)["flops"]
    ca = jax.jit(f).lower(*shapes).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla = float(ca.get("flops", 0.0))
    dots = 2 * 64 * 256 * 512 + 2 * 64 * 512 * 128
    assert abs(ours - xla) / xla < 0.05
    assert ours >= dots
