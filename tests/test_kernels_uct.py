"""Per-kernel sweeps: Pallas uct_select / uct_backup vs the pure-jnp oracle
(kernels/ref.py), bit-exact, across fanouts / depths / worker counts /
scoring variants.  Kernels run in interpret mode (CPU container; TPU is
the compile target).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TreeConfig, init_tree, intree, fixedpoint as fx
from repro.core.tree import NULL
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def grow_tree(cfg, supersteps=3, p=6, seed=0):
    """Grow a random-valued tree with the oracle jnp ops to get a
    non-trivial UCT state."""
    rng = np.random.RandomState(seed)
    tree = init_tree(cfg)
    for _ in range(supersteps):
        tree, sel = kref.select_ref(cfg, tree, p)
        tree, new_nodes = intree.insert_batch(cfg, tree, sel)
        sim_nodes = np.where(np.asarray(sel.expand_action) >= 0,
                             np.asarray(new_nodes[:, 0]),
                             np.asarray(sel.leaves)).astype(np.int32)
        vals = fx.encode(rng.uniform(-1, 1, p).astype(np.float32))
        tree = kref.backup_ref(cfg, tree, sel, jnp.asarray(sim_nodes),
                               jnp.asarray(np.asarray(vals)), False)
    return tree


TREE_SWEEP = [
    TreeConfig(X=64, F=2, D=3),
    TreeConfig(X=128, F=4, D=5),
    TreeConfig(X=128, F=6, D=4, vl_mode="constant", vl_const=0.5),
    TreeConfig(X=256, F=36, D=3, score_fn="puct", leaf_mode="unexpanded",
               expand_all=True),
]


@pytest.mark.parametrize("cfg", TREE_SWEEP,
                         ids=lambda c: f"F{c.F}-D{c.D}-{c.vl_mode}-{c.score_fn}")
@pytest.mark.parametrize("p", [1, 4, 16])
def test_select_kernel_matches_ref(cfg, p):
    tree = grow_tree(cfg, supersteps=2, p=4)
    t_ref, sel_ref = kref.select_ref(cfg, tree, p)
    t_k, sel_k = kops.select_batch(cfg, tree, p)
    np.testing.assert_array_equal(np.asarray(t_ref.edge_VL),
                                  np.asarray(t_k.edge_VL))
    np.testing.assert_array_equal(np.asarray(t_ref.node_O),
                                  np.asarray(t_k.node_O))
    for f in ("path_nodes", "path_actions", "depths", "leaves",
              "expand_action", "n_insert", "insert_base"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sel_ref, f)), np.asarray(getattr(sel_k, f)),
            err_msg=f)


@pytest.mark.parametrize("cfg", TREE_SWEEP[:3],
                         ids=lambda c: f"F{c.F}-D{c.D}-{c.vl_mode}")
@pytest.mark.parametrize("alternating", [False, True])
def test_backup_kernel_matches_ref(cfg, alternating):
    p = 6
    rng = np.random.RandomState(1)
    tree = grow_tree(cfg, supersteps=2, p=4)
    tree, sel = kref.select_ref(cfg, tree, p)
    tree, new_nodes = intree.insert_batch(cfg, tree, sel)
    sim_nodes = jnp.where(sel.expand_action >= 0, new_nodes[:, 0], sel.leaves)
    vals = jnp.asarray(np.asarray(
        fx.encode(rng.uniform(-1, 1, p).astype(np.float32))))

    t_ref = kref.backup_ref(cfg, tree, sel, sim_nodes, vals, alternating)
    t_k = kops.backup_batch(cfg, tree, sel, sim_nodes, vals, alternating)
    for f in ("edge_N", "edge_W", "edge_VL", "node_N", "node_O"):
        np.testing.assert_array_equal(
            np.asarray(getattr(t_ref, f)), np.asarray(getattr(t_k, f)),
            err_msg=f)


def test_packing_roundtrip():
    from repro.kernels import common as cm
    rng = np.random.RandomState(0)
    for x, f in [(64, 2), (100, 4), (48, 36), (128, 128)]:
        fp = 1
        while fp < f:
            fp *= 2
        arr = jnp.asarray(rng.randint(0, 100, (x, fp)), jnp.int32)
        packed = cm.pack_edges(arr, fp)
        assert packed.shape[1] == 128
        np.testing.assert_array_equal(
            np.asarray(cm.unpack_edges(packed, x, fp)), np.asarray(arr))
        node = jnp.asarray(rng.randint(0, 100, (x,)), jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(cm.unpack_nodes(cm.pack_nodes(node), x)),
            np.asarray(node))
