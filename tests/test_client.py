"""SearchClient handle API + global scheduler core.

Claim groups:

  * handle lifecycle — submit returns an opaque handle; cancel works
    before admission, mid-flight (committed moves survive) and is a
    no-op after completion; deadline budgets evict queued and in-flight
    requests; streamed moves() is bit-identical to the terminal trace;
  * scheduling — priorities reorder admission, every policy returns
    bit-identical per-request results (policies move WHEN work happens,
    never WHAT it computes), the weighted-queue-depth gang tick fuses
    one evaluate() batch across pools strictly larger than any single
    pool's, and fused vs per-pool evaluation is bit-identical;
  * retirement — idle pools release their arena after the TTL and are
    resurrected on demand, preserving every per-request result;
  * stats / deprecation — the monotonic ticks clock and admission-wait
    histogram survive aggregation; the legacy surfaces warn once.
"""

import warnings

import numpy as np
import pytest

from repro.core import TreeConfig
from repro.core.tree import bucket_key
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import (
    POLICY_NAMES, MoveEvent, SearchClient, SearchRequest, SearchService,
    ServiceFrontend,
)

ENV = BanditTreeEnv(fanout=3, terminal_depth=12)
P = 4

CFG_A = TreeConfig(X=96, F=3, D=5)
CFG_B = TreeConfig(X=64, F=3, D=4)
CFG_C = TreeConfig(X=48, F=3, D=6)
MIX = [CFG_A, CFG_B, CFG_C, CFG_A, CFG_B, CFG_C]


def _client(**kw):
    kw.setdefault("G", 2)
    kw.setdefault("p", P)
    kw.setdefault("default_cfg", CFG_A)
    return SearchClient(ENV, BanditValueBackend(), **kw)


def _assert_result_equal(got, want, label):
    assert got.actions == want.actions, label
    assert got.rewards == want.rewards, label
    assert got.supersteps == want.supersteps, label
    for va, vb in zip(got.visit_counts, want.visit_counts):
        np.testing.assert_array_equal(va, vb, err_msg=label)


# ---------------------------------------------------------------------------
# handle lifecycle
# ---------------------------------------------------------------------------

def test_submit_returns_handle_not_pool():
    cl = _client()
    h = cl.submit(SearchRequest(uid=0, seed=0, budget=2))
    assert not h.done()
    assert h.status() == "queued"
    res = h.result()                      # polls to completion
    assert h.done() and h.status() == "done"
    assert res.uid == 0 and res.actions and not res.cancelled
    assert "uid=0" in repr(h)
    cl.close()


def test_poll_budget_and_run_until():
    cl = _client()
    h = cl.submit(SearchRequest(uid=0, seed=1, budget=4))
    assert cl.poll(0) == 0                # no budget, no work done
    assert cl.poll(1) == 1                # one tick
    assert not h.done()
    assert cl.run_until(lambda c: h.done())
    assert cl.poll(5) == 0                # drained
    assert cl.run_until(lambda c: False, max_ticks=3) is False
    cl.close()


def test_handle_lookup_and_duplicate_uid_rejected():
    cl = _client()
    h = cl.submit(SearchRequest(uid=7, seed=0, budget=2))
    assert cl.handle(7) is h
    with pytest.raises(ValueError, match="already submitted"):
        cl.submit(SearchRequest(uid=7, seed=1, budget=2))
    h.result()
    with pytest.raises(ValueError, match="already submitted"):
        cl.submit(SearchRequest(uid=7, seed=1, budget=2))
    cl.close()


def test_cancel_before_admission():
    cl = _client(G=1)
    h0 = cl.submit(SearchRequest(uid=0, seed=0, budget=4))
    h1 = cl.submit(SearchRequest(uid=1, seed=1, budget=4))
    cl.poll(1)                            # uid=0 occupies the only slot
    assert h0.status() == "active" and h1.status() == "queued"
    assert h1.cancel() is True
    assert h1.status() == "cancelled" and h1.done()
    res = h1.result(wait=False)
    assert res.cancelled and not res.deadline_evicted
    assert res.actions == [] and res.supersteps == 0
    assert h1.cancel() is False           # already terminal
    assert h0.result().actions            # unaffected neighbour
    assert cl.stats.cancelled == 1
    cl.close()


def test_cancel_mid_flight_keeps_committed_moves():
    cl = _client(G=1)
    h = cl.submit(SearchRequest(uid=0, seed=2, budget=2, moves=4))
    cl.run_until(lambda c: len(c.core.move_log.get(0, [])) >= 2)
    assert h.status() == "active"
    assert h.cancel() is True
    res = h.result(wait=False)
    assert res.cancelled and len(res.actions) >= 2
    assert len(res.actions) < 4           # it really was cut short
    assert cl.core.pools[bucket_key(CFG_A)].load() == 0   # slot freed
    assert cl.poll(3) == 0                # nothing left to schedule
    cl.close()


def test_cancel_after_completion_is_noop():
    cl = _client()
    h = cl.submit(SearchRequest(uid=0, seed=3, budget=2))
    res = h.result()
    assert h.cancel() is False
    assert h.result(wait=False) is res and not res.cancelled
    cl.close()


def test_deadline_evicts_queued_request():
    cl = _client(G=1)
    cl.submit(SearchRequest(uid=0, seed=0, budget=8))
    h1 = cl.submit(SearchRequest(uid=1, seed=1, budget=2),
                   deadline_supersteps=3)
    cl.run_until(lambda c: h1.done())
    assert h1.status() == "evicted"
    res = h1.result(wait=False)
    assert res.cancelled and res.deadline_evicted and res.actions == []
    assert cl.stats.deadline_evictions == 1
    cl.close()


def test_deadline_evicts_in_flight_request_keeping_moves():
    cl = _client(G=1)
    h = cl.submit(SearchRequest(uid=0, seed=4, budget=2, moves=8),
                  deadline_supersteps=5)
    cl.run_until(lambda c: h.done())
    res = h.result(wait=False)
    assert h.status() == "evicted" and res.deadline_evicted
    assert 1 <= len(res.actions) < 8      # partial progress survived
    cl.close()


def test_generous_deadline_never_fires():
    cl = _client()
    h = cl.submit(SearchRequest(uid=0, seed=5, budget=2),
                  deadline_supersteps=10_000)
    res = h.result()
    assert h.status() == "done" and not res.cancelled
    assert cl.stats.deadline_evictions == 0
    cl.close()


# ---------------------------------------------------------------------------
# streamed moves()
# ---------------------------------------------------------------------------

def test_moves_stream_bit_identical_to_terminal_trace():
    """Acceptance: the per-move events streamed as reroots commit carry
    exactly the terminal result's action / reward / visit-distribution
    trace, in order, with `last` marking the final move."""
    cl = _client()
    h = cl.submit(SearchRequest(uid=0, seed=6, budget=3, moves=3))
    events = list(h.moves())              # iterating IS serving
    assert h.done()
    res = h.result(wait=False)
    assert [e.action for e in events] == res.actions
    assert [e.reward for e in events] == res.rewards
    assert [e.move_index for e in events] == list(range(len(res.actions)))
    for ev, vc in zip(events, res.visit_counts):
        assert isinstance(ev, MoveEvent)
        np.testing.assert_array_equal(ev.visit_counts, vc)
    assert [e.last for e in events] == [False, False, True]
    cl.close()


def test_moves_stream_interleaves_with_other_requests():
    """Events stream per handle even when several requests share the
    scheduler; a second pass over moves() replays from the buffer."""
    cl = _client()
    hs = [cl.submit(SearchRequest(uid=i, seed=10 + i, budget=2, moves=2))
          for i in range(3)]
    traces = {h.uid: [e.action for e in h.moves()] for h in hs}
    for h in hs:
        assert traces[h.uid] == h.result(wait=False).actions
        assert [e.action for e in h.moves()] == traces[h.uid]   # replay
    cl.close()


def test_moves_stream_ends_on_cancel():
    cl = _client(G=1)
    h = cl.submit(SearchRequest(uid=0, seed=2, budget=2, moves=6))
    it = h.moves()
    first = next(it)
    assert first.move_index == 0 and not first.last
    h.cancel()
    rest = list(it)                       # stream ends, no hang
    assert [e.move_index for e in rest] == \
        list(range(1, len(h.result(wait=False).actions)))
    cl.close()


# ---------------------------------------------------------------------------
# scheduling: priorities + policies + cross-pool fusion
# ---------------------------------------------------------------------------

def test_priority_reorders_admission():
    cl = _client(G=1)
    cl.submit(SearchRequest(uid=0, seed=0, budget=2))
    cl.poll(1)                                             # uid=0 occupies
    cl.submit(SearchRequest(uid=1, seed=1, budget=2))      # default class
    cl.submit(SearchRequest(uid=2, seed=2, budget=2), priority=5)
    done = [r.uid for r in cl.drain()]
    assert done == [0, 2, 1]              # priority 5 jumps the queue
    cl.close()


def _mix_requests():
    return [SearchRequest(uid=i, seed=20 + i, budget=3, moves=1 + i % 2,
                          cfg=cfg)
            for i, cfg in enumerate(MIX)]


def _dedicated_results():
    out = {}
    for req in _mix_requests():
        svc = SearchService(req.cfg, ENV, BanditValueBackend(), G=1, p=P)
        try:
            svc.submit(SearchRequest(uid=req.uid, seed=req.seed,
                                     budget=req.budget, moves=req.moves))
            (out[req.uid],) = svc.run()
        finally:
            svc.close()
    return out


def test_every_policy_matches_dedicated_services():
    """Policies move WHEN work happens, never WHAT it computes: the same
    heterogeneous mix under every policy equals dedicated single-config
    runs of each request, bit for bit."""
    want = _dedicated_results()
    for policy in POLICY_NAMES:
        cl = _client(G=2, policy=policy)
        try:
            handles = [cl.submit(req) for req in _mix_requests()]
            for h in handles:
                _assert_result_equal(h.result(), want[h.uid],
                                     f"{policy} uid={h.uid}")
        finally:
            cl.close()


def test_weighted_policy_fuses_across_pools():
    """The gang tick really fuses: one evaluate() spans >1 pool, and the
    fused batch is strictly larger than its largest single-pool share."""
    cl = _client(G=2, policy="weighted-queue-depth")
    for req in _mix_requests():
        cl.submit(req)
    cl.drain()
    core = cl.core
    assert core.xpool_batches > 0
    assert core.xpool_rows_max > core.xpool_pool_rows_max > 0
    # the aggregate view surfaces the fused batches too
    assert cl.stats.max_fused_rows == core.xpool_rows_max
    cl.close()


def test_fused_vs_per_pool_evaluate_bit_identical():
    """Acceptance: switching the gang tick between ONE cross-pool fused
    evaluate and per-pool evaluate changes nothing per request."""
    def go(fuse):
        cl = _client(G=2, policy="weighted-queue-depth",
                     fuse_across_pools=fuse)
        try:
            hs = [cl.submit(req) for req in _mix_requests()]
            return {h.uid: h.result() for h in hs}, cl.core.xpool_batches
        finally:
            cl.close()

    fused, nb_fused = go(True)
    split, nb_split = go(False)
    assert nb_fused > 0 and nb_split == 0
    for uid in fused:
        _assert_result_equal(fused[uid], split[uid], f"uid={uid}")


def test_weighted_policy_sizes_buckets_by_queue_depth():
    """Per-bucket G sizing: a bucket holding most of the backlog may fill
    its slots; a one-request bucket is capped to its fair share (>= 1)."""
    cl = _client(G=4, policy="weighted-queue-depth")
    for i in range(8):
        cl.submit(SearchRequest(uid=i, seed=i, budget=3, cfg=CFG_A))
    cl.submit(SearchRequest(uid=8, seed=8, budget=3, cfg=CFG_B))
    cl.poll(1)
    a = cl.core.pools[bucket_key(CFG_A)]
    b = cl.core.pools[bucket_key(CFG_B)]
    assert a.load() > b.load() >= 1       # depth-weighted, nobody starves
    assert b.admit_limit < a.admit_limit <= a.G
    assert len(cl.drain()) == 9           # sizing never loses a request
    cl.close()


def test_fairness_floor_prevents_admission_starvation():
    """Regression: a share-of-backlog cap of 1 is satisfied by a
    bucket's single long-running ACTIVE request, so its queued work used
    to starve behind a dominating bucket until that request finished.
    The fairness floor guarantees room for one fresh admission per gang
    tick; fairness_floor=False reproduces the starvation."""
    from repro.service.scheduler_core import WeightedQueueDepthPolicy

    def go(floor):
        cl = _client(G=4, policy=WeightedQueueDepthPolicy(
            fairness_floor=floor))
        # bucket B: one long-running request holds its only fair-share
        # slot, one small request queues behind it
        b1 = cl.submit(SearchRequest(uid=100, seed=1, budget=60, cfg=CFG_B))
        # bucket A dominates the depth share (cap_B stays at the floor)
        for i in range(12):
            cl.submit(SearchRequest(uid=i, seed=i, budget=20, cfg=CFG_A))
        b2 = cl.submit(SearchRequest(uid=101, seed=2, budget=2, cfg=CFG_B))
        cl.poll(6)
        b2_admitted = b2.status() != "queued"
        assert b1.status() == "active"    # B1 still occupies its slot
        assert len(cl.drain()) == 14      # floor or not, nothing is lost
        cl.close()
        return b2_admitted

    assert go(True)        # B2 admitted alongside B1 within a few ticks
    assert not go(False)   # starved behind B1 at the old share cap


def test_deadline_aware_policy_prefers_urgent_bucket():
    """The pool holding the nearest deadline advances first on every
    tick, so an urgent request on a cold bucket overtakes a deep default
    bucket."""
    cl = _client(G=1, policy="deadline-aware")
    for i in range(4):
        cl.submit(SearchRequest(uid=i, seed=i, budget=4, cfg=CFG_A))
    h = cl.submit(SearchRequest(uid=9, seed=9, budget=4, cfg=CFG_B),
                  deadline_supersteps=40)
    cl.drain()
    assert h.status() == "done"           # made its deadline
    by_finish = sorted(cl.core.completed, key=lambda r: r.done_at)
    assert by_finish[0].uid == 9          # urgent bucket went first
    cl.close()


# ---------------------------------------------------------------------------
# cold-pool retirement
# ---------------------------------------------------------------------------

def test_idle_pool_retires_and_resurrects_preserving_results():
    """Acceptance: an idle bucket releases its arena after the TTL
    (executor freed, session closed), keeps every completed result, and
    is resurrected on the next submit with bit-identical behavior."""
    cl = _client(G=2, retire_after_ticks=3)
    hb = cl.submit(SearchRequest(uid=0, seed=0, budget=2, cfg=CFG_B))
    cl.submit(SearchRequest(uid=1, seed=1, budget=40, cfg=CFG_A))
    key_b = bucket_key(CFG_B)
    cl.run_until(lambda c: c.core.pools[key_b].retired)
    pool_b = cl.core.pools[key_b]
    assert pool_b.exec is None and pool_b.sts is None
    assert cl.stats.retirements == 1
    assert hb.result(wait=False).actions            # result survived
    # resurrect on demand: same bucket, fresh arena, same computation
    hb2 = cl.submit(SearchRequest(uid=2, seed=0, budget=2, cfg=CFG_B))
    assert pool_b.retired is False and pool_b.exec is not None
    res2 = hb2.result()
    _assert_result_equal(res2, hb.result(wait=False), "resurrected run")
    assert cl.handle(0).status() == "done"          # old handle intact
    cl.close()


def test_busy_pool_never_retires():
    cl = _client(G=2, retire_after_ticks=1)
    h = cl.submit(SearchRequest(uid=0, seed=0, budget=6, moves=2))
    h.result()
    # the pool idles only after its work drained; no ticks follow, so it
    # stays live (retirement needs the scheduler to keep ticking)
    assert not cl.core.pools[bucket_key(CFG_A)].retired
    assert cl.stats.retirements == 0
    cl.close()


def test_result_ttl_expires_retired_pool_results():
    """Result TTL: a retired bucket's completed results are dropped after
    `result_ttl_ticks` global ticks — handle reports "expired" (done()
    stays True), result() raises, the move log is freed, and the expiry
    is counted in the registry.  Live buckets are untouched."""
    cl = _client(G=2, retire_after_ticks=2, result_ttl_ticks=4,
                 metrics=True)
    hb = cl.submit(SearchRequest(uid=0, seed=0, budget=2, cfg=CFG_B))
    h_long = cl.submit(SearchRequest(uid=1, seed=1, budget=60, cfg=CFG_A))
    cl.run_until(lambda c: c.handle(0).status() == "expired")
    assert hb.status() == "expired" and hb.done()
    with pytest.raises(RuntimeError, match="expired"):
        hb.result(wait=False)
    assert 0 not in cl.core.results and 0 not in cl.core.move_log
    assert cl.core.pools[bucket_key(CFG_B)].completed == []
    assert cl.registry.get("service_expired_results_total").value == 1
    # the still-live bucket keeps its result forever (pool never retired)
    assert h_long.result().actions and h_long.status() == "done"
    cl.close()


def test_no_ttl_keeps_retired_pool_results_forever():
    cl = _client(G=2, retire_after_ticks=2)        # result_ttl_ticks=None
    hb = cl.submit(SearchRequest(uid=0, seed=0, budget=2, cfg=CFG_B))
    cl.submit(SearchRequest(uid=1, seed=1, budget=40, cfg=CFG_A))
    cl.drain()
    assert cl.core.pools[bucket_key(CFG_B)].retired
    assert hb.status() == "done" and hb.result(wait=False).actions
    cl.close()


def test_moves_stream_survives_ttl_expiry_mid_iteration():
    """Regression: the result TTL pops move_log[uid] from the dict while
    a live moves() iterator may still be draining that list.  The
    iterator must hold the list object it first resolved — the expiry
    unlinks the dict entry but never mutates the list — so no event the
    iterator hasn't yielded yet is silently truncated."""
    cl = _client(G=2, retire_after_ticks=2, result_ttl_ticks=2)
    hb = cl.submit(SearchRequest(uid=0, seed=0, budget=2, moves=3,
                                 cfg=CFG_B))
    cl.submit(SearchRequest(uid=1, seed=1, budget=80, cfg=CFG_A))
    it = hb.moves()
    first = next(it)                     # iterator now holds the log list
    res = hb.result()                    # drive uid 0 to completion
    cl.run_until(lambda c: c.handle(0).status() == "expired")
    assert 0 not in cl.core.move_log     # the dict entry IS gone...
    got = [first] + list(it)             # ...but the stream is complete
    assert [e.action for e in got] == res.actions
    assert [e.move_index for e in got] == list(range(len(res.actions)))
    cl.close()


def test_retired_pool_probes_are_safe():
    """Regression: status() and the deadline-aware policy used to probe
    pool.slots directly, which a retired pool has released with its
    arena.  The retired-safe accessors (ArenaPool.holds /
    deadline_ticks) answer without touching freed state."""
    import math

    from repro.service.scheduler_core import DeadlineAwarePolicy

    cl = _client(G=2, retire_after_ticks=2)
    hb = cl.submit(SearchRequest(uid=0, seed=0, budget=2, cfg=CFG_B))
    cl.submit(SearchRequest(uid=1, seed=1, budget=40, cfg=CFG_A))
    key_b = bucket_key(CFG_B)
    cl.run_until(lambda c: c.core.pools[key_b].retired)
    pool = cl.core.pools[key_b]
    assert pool.exec is None             # arena really released
    assert pool.holds(0) is False
    assert pool.deadline_ticks() == []
    assert hb.status() == "done"         # handle probe on a retired pool
    assert DeadlineAwarePolicy()._slack(cl.core, key_b) == math.inf
    cl.close()


# ---------------------------------------------------------------------------
# EWMA-smoothed weighted-queue-depth admission caps
# ---------------------------------------------------------------------------

class _FakePool:
    def __init__(self, cfg, G, queued):
        self.cfg, self.G = cfg, G
        self.queue = [None] * queued

    def has_work(self):
        return True

    def load(self):
        return 0


class _FakeCore:
    def __init__(self, pools):
        self.pools = pools
        self._order = list(pools)
        self.ticks = 1
        from repro.obs import MetricsRegistry
        self.registry = MetricsRegistry()


def test_weighted_policy_smooths_admission_caps():
    """EWMA smoothing: when a bucket's burst drains in one tick, its cap
    decays over several ticks instead of collapsing straight to the
    floor, the EWMA is seeded with the first observed depth (tick 1
    behaves exactly as unsmoothed), the update advances at most once per
    tick, and the smoothed load is exported as a per-bucket gauge."""
    from repro.service.pool import bucket_label
    from repro.service.scheduler_core import WeightedQueueDepthPolicy

    pol = WeightedQueueDepthPolicy(ewma_alpha=0.5)
    a, b = _FakePool(CFG_A, 4, 8), _FakePool(CFG_B, 4, 8)
    core = _FakeCore({"a": a, "b": b})
    assert pol.admit_limits(core) == {"a": 2, "b": 2}   # seeded = unsmoothed
    b.queue = []          # the whole burst drains out of bucket b at once
    caps = []
    for tick in range(2, 6):
        core.ticks = tick
        caps.append(pol.admit_limits(core)["b"])
    # unsmoothed would slam to the floor (1) immediately; EWMA decays
    assert caps[0] > 1
    assert all(x >= y for x, y in zip(caps, caps[1:]))  # monotone decay
    assert caps[-1] >= 1
    # idempotent within a tick: probing again does not advance the EWMA
    assert pol.admit_limits(core)["b"] == caps[-1]
    gauge = core.registry.get("service_smoothed_load",
                              bucket=bucket_label(CFG_B))
    assert gauge is not None and 0 < gauge.value < 8
    # alpha=1 recovers the unsmoothed behavior; out-of-range rejected
    flat = WeightedQueueDepthPolicy(ewma_alpha=1.0)
    assert flat.admit_limits(core)["b"] == 1
    with pytest.raises(ValueError):
        WeightedQueueDepthPolicy(ewma_alpha=0.0)


def test_weighted_policy_prunes_drained_bucket_ewma():
    """Regression: _ewma entries for buckets that drained or retired
    were never pruned, so a bucket resurrected after idling reused the
    stale smoothed depth from its previous life and skewed every
    bucket's admission share.  No-work buckets are dropped each tick;
    a returning bucket reseeds from its fresh backlog."""
    from repro.service.scheduler_core import WeightedQueueDepthPolicy

    pol = WeightedQueueDepthPolicy(ewma_alpha=0.5)
    a, b = _FakePool(CFG_A, 4, 8), _FakePool(CFG_B, 4, 8)
    core = _FakeCore({"a": a, "b": b})
    pol.admit_limits(core)
    assert set(pol._ewma) == {"a", "b"}
    # bucket b drains (and, in the real core, retires): its entry goes
    b.queue = []
    b.has_work = lambda: False
    core.ticks = 2
    pol.admit_limits(core)
    assert set(pol._ewma) == {"a"}
    # resurrection: fresh backlog of 2 seeds the EWMA at 2 — NOT a decay
    # from the dead bucket's smoothed depth of 8
    b.queue = [None] * 2
    b.has_work = lambda: True
    core.ticks = 3
    pol.admit_limits(core)
    assert pol._ewma["b"] == 2


# ---------------------------------------------------------------------------
# D-sharded serving: least-loaded placement + failover
# ---------------------------------------------------------------------------

def test_shard_placement_balances_load():
    """Admissions go to the least-loaded enabled shard (ties break to
    the lowest shard id, then lowest free slot): four requests into a
    G=4 / D=2 pool alternate shards instead of filling shard 0 first."""
    cl = _client(G=4, n_shards=2)
    for i in range(4):
        cl.submit(SearchRequest(uid=i, seed=i, budget=30, moves=2))
    cl.poll(1)                            # first tick admits everything
    (pool,) = cl.core.pools.values()
    assert pool.n_shards == 2 and pool.shard_G == 2
    assert pool.shard_loads() == [2, 2]
    # uid 0 -> shard 0 (tie, lowest id), uid 1 -> shard 1 (now least
    # loaded), uid 2 -> shard 0 again, uid 3 -> shard 1
    assert [s.req.uid for s in pool.slots] == [0, 2, 1, 3]
    assert [pool.shard_of(g) for g in range(4)] == [0, 0, 1, 1]
    cl.close()


def test_shard_failover_disable_and_reenable():
    """set_shard_enabled steers admission around a drained shard: with
    shard 0 disabled every new request lands on shard 1; re-enabling
    restores least-loaded placement.  Results complete either way —
    placement never touches semantics."""
    cl = _client(G=4, n_shards=2)
    cl.submit(SearchRequest(uid=0, seed=0, budget=3))
    (pool,) = cl.core.pools.values()
    pool.set_shard_enabled(0, False)
    cl.submit(SearchRequest(uid=1, seed=1, budget=3))
    cl.poll(1)
    assert pool.shard_loads() == [0, 2]   # both on shard 1
    assert all(s is None for s in pool.slots[:2])
    pool.set_shard_enabled(0, True)
    cl.submit(SearchRequest(uid=2, seed=2, budget=3))
    cl.poll(1)
    assert pool.shard_loads()[0] == 1     # shard 0 takes work again
    done = {r.uid for r in cl.drain()}
    assert {0, 1, 2} <= done
    cl.close()


def test_shard_count_must_divide_g():
    with pytest.raises(ValueError, match="n_shards"):
        cl = _client(G=3, n_shards=2)
        cl.submit(SearchRequest(uid=0, seed=0, budget=2))


def test_resurrected_pool_keeps_shard_partition():
    """A retired sharded pool resurrects with the same D-way partition
    (the arena is rebuilt through the same factory arguments)."""
    cl = _client(G=4, n_shards=2, retire_after_ticks=2)
    cl.submit(SearchRequest(uid=0, seed=0, budget=2, cfg=CFG_B))
    cl.submit(SearchRequest(uid=1, seed=1, budget=40, cfg=CFG_A))
    key_b = bucket_key(CFG_B)
    cl.run_until(lambda c: c.core.pools[key_b].retired)
    h = cl.submit(SearchRequest(uid=2, seed=0, budget=2, cfg=CFG_B))
    pool = cl.core.pools[key_b]
    assert not pool.retired
    assert getattr(pool.exec, "n_shards", 1) == 2
    assert h.result().actions
    cl.close()


# ---------------------------------------------------------------------------
# tick budgets bound the CLOCK (fused dispatch advances it by up to K)
# ---------------------------------------------------------------------------

def test_run_max_ticks_bounds_clock_not_calls():
    """Regression: run(max_ticks) counted tick() CALLS, but one fused
    dispatch advances the clock by up to K — K=4 could burn 4x the
    stated budget.  The loop is now bounded against core.ticks and may
    overshoot by at most one dispatch."""
    cl = _client(G=2, supersteps_per_dispatch=4)
    cl.submit(SearchRequest(uid=0, seed=0, budget=60, moves=2))
    cl.core.run(max_ticks=8)
    assert cl.stats.fused_dispatches > 0  # the fused path really drove this
    assert cl.core.ticks < 8 + 4
    cl.close()


def test_result_max_ticks_bounds_clock_under_fused_dispatch():
    """Same bug on the handle: result(max_ticks) counted poll() calls.
    A request far from completion must stop within ~max_ticks of clock,
    not max_ticks dispatches."""
    cl = _client(G=2, supersteps_per_dispatch=8)
    h = cl.submit(SearchRequest(uid=0, seed=0, budget=200, moves=4))
    with pytest.raises(RuntimeError, match="no result"):
        h.result(max_ticks=16)
    assert cl.core.ticks < 16 + 8
    cl.close()


# ---------------------------------------------------------------------------
# overlap mode: budget exits drain the in-flight gang WITHOUT ticks
# ---------------------------------------------------------------------------

def _no_inflight(cl):
    return all(p._inflight is None and p._inflight_fused is None
               for p in cl.core.pools.values() if not p.retired)


def test_run_max_ticks_drains_inflight_gang_within_budget():
    """Regression (extends the PR 8 clock-bound fix to overlap): when
    run(max_ticks) expires mid-pipeline, the in-flight gang's applied
    selection/insertion must be completed — but by drain_overlap, which
    advances NO ticks, so the clock stays within the stated budget."""
    cl = _client(G=2, overlap=True)
    cl.submit(SearchRequest(uid=0, seed=0, budget=60, moves=2))
    cl.core.run(max_ticks=6)
    assert cl.core.ticks <= 6           # phase-path ticks are exactly 1
    assert _no_inflight(cl)             # ...and nothing was left applied
    assert cl.stats.supersteps > 0
    cl.close()


def test_result_max_ticks_drains_inflight_gang():
    """Same contract on the handle: result(max_ticks) exhausting its
    budget under overlap raises, stays within the clock bound, and
    leaves no gang in flight (its superstep completed tick-free)."""
    cl = _client(G=2, overlap=True)
    h = cl.submit(SearchRequest(uid=0, seed=0, budget=200, moves=4))
    with pytest.raises(RuntimeError, match="no result"):
        h.result(max_ticks=8)
    assert cl.core.ticks <= 8
    assert _no_inflight(cl)
    cl.close()


def test_run_until_budget_exit_drains_inflight_gang():
    """run_until's budget/drain exit calls drain_inflight before the
    final predicate check — the predicate observes a consistent pool."""
    cl = _client(G=2, overlap=True)
    cl.submit(SearchRequest(uid=0, seed=0, budget=100, moves=3))
    assert cl.run_until(lambda c: False, max_ticks=5) is False
    assert cl.core.ticks <= 5
    assert _no_inflight(cl)
    cl.close()


def test_run_max_ticks_bounds_clock_under_fused_overlap():
    """Overlap composes with the fused K-dispatch clock rule: one
    overlap tick collects the PREVIOUS gang's K-superstep dispatch, so
    the clock may overshoot by at most one dispatch — and the staged
    gang left in flight at budget expiry is drained tick-free."""
    cl = _client(G=2, overlap=True, supersteps_per_dispatch=4)
    cl.submit(SearchRequest(uid=0, seed=0, budget=60, moves=2))
    cl.core.run(max_ticks=8)
    assert cl.stats.fused_dispatches > 0
    assert cl.core.ticks < 8 + 4
    assert _no_inflight(cl)
    cl.close()


def test_overlap_results_match_lockstep_through_client():
    """The handle API returns bit-identical results with overlap on —
    gangs reschedule WHEN slots advance, never WHAT they compute."""
    reqs = [dict(uid=i, seed=i, budget=3 + i % 3, moves=1 + i % 2)
            for i in range(5)]

    def go(**kw):
        cl = _client(G=2, **kw)
        try:
            hs = [cl.submit(SearchRequest(**r)) for r in reqs]
            return {h.uid: h.result() for h in hs}
        finally:
            cl.close()

    want = go()
    got = go(overlap=True, n_gangs=2)
    for uid in want:
        _assert_result_equal(got[uid], want[uid], f"overlap uid={uid}")


def test_overlap_rejects_compaction():
    """Overlap pins slot rows while a gang is in flight — combining it
    with compaction (which moves rows) must fail loudly at build time."""
    with pytest.raises(ValueError, match="compact"):
        cl = _client(G=2, overlap=True, compact_threshold=0.5)
        cl.submit(SearchRequest(uid=0, seed=0, budget=2))
        cl.poll(1)
        cl.close()


# ---------------------------------------------------------------------------
# stats: monotonic ticks + wait histogram
# ---------------------------------------------------------------------------

def test_ticks_clock_and_wait_histogram():
    cl = _client(G=1)
    for i in range(3):
        cl.submit(SearchRequest(uid=i, seed=i, budget=2))
    cl.drain()
    s = cl.stats
    assert s.ticks == cl.core.ticks > 0   # the core's clock, not a sum
    assert sum(s.wait_supersteps.values()) == s.admitted == 3
    # G=1 serializes: the 2nd and 3rd request measurably waited
    assert max(s.wait_supersteps) > 0
    assert s.wait_percentile(0) <= s.wait_percentile(50) \
        <= s.wait_percentile(95) == max(s.wait_supersteps)
    cl.close()


def test_wait_histogram_merges_across_pools():
    from repro.service import ServiceStats
    a = ServiceStats(wait_supersteps={0: 2, 3: 1})
    b = ServiceStats(wait_supersteps={3: 2, 5: 1})
    m = a.merge(b)
    assert m.wait_supersteps == {0: 2, 3: 3, 5: 1}
    assert ServiceStats().wait_percentile(95) == 0


def test_pool_load_is_public_and_summaries_use_it():
    cl = _client(G=2)
    cl.submit(SearchRequest(uid=0, seed=0, budget=4))
    cl.poll(1)
    pool = cl.core.pools[bucket_key(CFG_A)]
    assert pool.load() == 1
    (summary,) = cl.pool_summaries()
    assert summary["active"] == 1 and summary["retired"] is False
    cl.drain()
    assert pool.load() == 0
    cl.close()


# ---------------------------------------------------------------------------
# deprecation surface
# ---------------------------------------------------------------------------

def test_search_service_warns_once_pointing_at_client():
    SearchService._warned = False
    with pytest.warns(DeprecationWarning, match="SearchClient"):
        svc = SearchService(CFG_A, ENV, BanditValueBackend(), G=1, p=P)
    svc.close()
    with warnings.catch_warnings():
        warnings.simplefilter("error")    # second construction is silent
        SearchService(CFG_A, ENV, BanditValueBackend(), G=1, p=P).close()


def test_arena_shim_warns_once_on_legacy_import():
    import repro.service.arena as arena
    arena._warned = False
    with pytest.warns(DeprecationWarning, match="core.executor"):
        make = arena.make_arena_executor
    ex = make(CFG_A, 1, "reference")
    assert ex.G == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert arena.JaxArenaExecutor is not None   # silent after first
    with pytest.raises(AttributeError):
        arena.not_a_name


def test_init_exports_new_names_first():
    import repro.service as service
    exported = service.__all__
    assert exported.index("SearchClient") == 0
    assert exported.index("SearchClient") < exported.index("ServiceFrontend")
    assert exported.index("SchedulerCore") < exported.index("SearchService")


def test_frontend_is_adapter_over_client():
    fe = ServiceFrontend(ENV, BanditValueBackend(), G=2, p=P,
                         default_cfg=CFG_A)
    assert isinstance(fe.client, SearchClient)
    pool = fe.submit(SearchRequest(uid=0, seed=0, budget=2))
    assert pool is fe.pools[bucket_key(CFG_A)]
    (res,) = fe.run()
    assert res.uid == 0
    assert fe.stats.ticks == fe.core.ticks
    fe.close()
