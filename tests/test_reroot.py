"""Direct unit tests for core/reroot.py (subtree-reusing Tree Flush).

reroot() was previously only exercised end-to-end through
TreeParallelMCTS.run_step / the service move-advance; these tests pin its
contract directly: statistics preserved under subtree extraction, the
id-compaction map is a consistent bijection onto the surviving nodes, and
degenerate new roots (leaf with all-NULL children) work.
"""

import numpy as np

from repro.core import TreeConfig, TreeParallelMCTS
from repro.core.reroot import reroot
from repro.core.tree import NULL
from repro.envs import BanditTreeEnv, BanditValueBackend

CFG = TreeConfig(X=256, F=4, D=6)

_STAT_KEYS = ("edge_N", "edge_W", "edge_VL", "edge_P", "node_N", "node_O",
              "num_expanded", "num_actions", "terminal")


def _grown_snapshot(supersteps=8, seed=5):
    env = BanditTreeEnv(fanout=4, terminal_depth=10)
    m = TreeParallelMCTS(CFG, env, BanditValueBackend(), p=8,
                         executor="faithful", seed=seed)
    for _ in range(supersteps):
        m.superstep()
    return m.exec.snapshot(m.tree)


def _reachable(child, root):
    seen, stack = {int(root)}, [int(root)]
    while stack:
        for c in child[stack.pop()]:
            if c != NULL and int(c) not in seen:
                seen.add(int(c))
                stack.append(int(c))
    return seen


def test_statistics_preserved_under_subtree_extraction():
    snap = _grown_snapshot()
    new_root = int(snap["child"][int(snap["root"]), 1])
    assert new_root != NULL
    out, old2new = reroot(CFG, snap, new_root)

    reach = _reachable(snap["child"], new_root)
    assert int(out["size"]) == len(reach)
    assert int(out["root"]) == 0 and old2new[new_root] == 0
    for old in reach:
        new = int(old2new[old])
        for k in _STAT_KEYS:
            np.testing.assert_array_equal(
                out[k][new], snap[k][old], err_msg=f"{k} old={old}")
    # depths re-based to the new root
    for old in reach:
        assert out["node_depth"][old2new[old]] == (
            snap["node_depth"][old] - snap["node_depth"][new_root])
    # dropped region is zeroed / NULL (capacity reclaimed)
    n = len(reach)
    assert (out["child"][n:] == NULL).all()
    assert out["node_N"][n:].sum() == 0 and out["edge_N"][n:].sum() == 0


def test_id_compaction_map_correctness():
    snap = _grown_snapshot(seed=9)
    new_root = int(snap["child"][int(snap["root"]), 0])
    out, old2new = reroot(CFG, snap, new_root)

    reach = _reachable(snap["child"], new_root)
    n = len(reach)
    # bijection: exactly the reachable set maps, onto 0..n-1 without gaps
    mapped = np.flatnonzero(old2new != NULL)
    assert set(mapped.tolist()) == reach
    assert sorted(old2new[mapped].tolist()) == list(range(n))
    # child links are remapped through the same map
    for old in reach:
        new = int(old2new[old])
        for f in range(CFG.Fp):
            c = int(snap["child"][old, f])
            expect = NULL if c == NULL else int(old2new[c])
            assert int(out["child"][new, f]) == expect, (old, f)
    # dropped nodes (outside the subtree) have no image
    dropped = set(range(int(snap["size"]))) - reach
    assert all(old2new[o] == NULL for o in dropped)


def test_reroot_onto_leaf_with_null_children():
    """New root is an unexpanded frontier node: the result is a size-1
    tree that still carries that node's own statistics."""
    snap = _grown_snapshot(supersteps=3, seed=2)
    size = int(snap["size"])
    leaves = [i for i in range(size) if (snap["child"][i] == NULL).all()]
    assert leaves
    new_root = leaves[-1]
    out, old2new = reroot(CFG, snap, new_root)
    assert int(out["size"]) == 1
    assert int(out["root"]) == 0 and old2new[new_root] == 0
    assert (out["child"] == NULL).all()
    for k in _STAT_KEYS:
        np.testing.assert_array_equal(out[k][0], snap[k][new_root], err_msg=k)
    assert out["node_depth"][0] == 0
    assert (old2new != NULL).sum() == 1


def test_reroot_with_inflight_virtual_loss_outstanding():
    """Re-root taken mid-superstep, after Selection applied virtual loss
    but before BackUp recovered it: the in-flight counters (edge_VL,
    node_O) are statistics like any other and must survive extraction —
    a driver that reroots here must not strand or invent in-flight work."""
    env = BanditTreeEnv(fanout=4, terminal_depth=10)
    m = TreeParallelMCTS(CFG, env, BanditValueBackend(), p=8,
                         executor="faithful", seed=5)
    for _ in range(6):
        m.superstep()
    # half a superstep: Selection marks in-flight workers, no BackUp yet
    active = np.ones(1, bool)
    m.exec.selection(active, p=8)
    snap = m.exec.snapshot(m.tree)
    assert snap["edge_VL"].sum() > 0 and snap["node_O"].sum() > 0

    new_root = int(snap["child"][int(snap["root"]), 0])
    assert new_root != NULL
    out, old2new = reroot(CFG, snap, new_root)
    reach = _reachable(snap["child"], new_root)
    for old in reach:
        new = int(old2new[old])
        np.testing.assert_array_equal(out["edge_VL"][new],
                                      snap["edge_VL"][old], err_msg=str(old))
        assert out["node_O"][new] == snap["node_O"][old]
    # in-flight totals outside the subtree are dropped with their nodes,
    # never remapped onto survivors
    kept_vl = sum(int(snap["edge_VL"][o].sum()) for o in reach)
    assert int(out["edge_VL"].sum()) == kept_vl
    kept_o = sum(int(snap["node_O"][o]) for o in reach)
    assert int(out["node_O"].sum()) == kept_o


def test_reroot_at_full_tree_capacity():
    """Tree grown to the X node cap (saturated supersteps included): the
    reroot map must stay a bijection onto the surviving subtree and free
    real capacity for the next move."""
    cfg = TreeConfig(X=48, F=4, D=6)
    env = BanditTreeEnv(fanout=4, terminal_depth=10)
    m = TreeParallelMCTS(cfg, env, BanditValueBackend(), p=8,
                         executor="faithful", seed=1)
    prev = 0
    for _ in range(64):
        m.superstep()
        size = int(np.asarray(m.tree.size))
        if size == prev:  # saturated: no free ids left (or all leaves dead)
            break
        prev = size
    snap = m.exec.snapshot(m.tree)
    assert int(snap["size"]) == cfg.X, "schedule must fill the tree"

    root = int(snap["root"])
    kids = [int(c) for c in snap["child"][root] if c != NULL]
    assert kids
    new_root = kids[0]
    out, old2new = reroot(cfg, snap, new_root)
    reach = _reachable(snap["child"], new_root)
    assert int(out["size"]) == len(reach) < cfg.X  # capacity reclaimed
    mapped = np.flatnonzero(old2new != NULL)
    assert set(mapped.tolist()) == reach
    assert sorted(old2new[mapped].tolist()) == list(range(len(reach)))
    for old in reach:
        new = int(old2new[old])
        for k in _STAT_KEYS:
            np.testing.assert_array_equal(out[k][new], snap[k][old],
                                          err_msg=f"{k} old={old}")
    # the freed region is genuinely reusable: zeroed stats, NULL links
    n = len(reach)
    assert (out["child"][n:] == NULL).all()
    assert out["node_N"][n:].sum() == 0 and out["edge_N"][n:].sum() == 0
    assert out["edge_VL"][n:].sum() == 0 and out["node_O"][n:].sum() == 0


def test_reroot_is_idempotent_on_root():
    """Re-rooting at the current root is a pure id-compaction no-op for a
    BFS-ordered tree prefix: statistics and links survive unchanged."""
    snap = _grown_snapshot(supersteps=4, seed=11)
    out, old2new = reroot(CFG, snap, int(snap["root"]))
    assert int(out["size"]) == int(snap["size"])
    reach = _reachable(snap["child"], int(snap["root"]))
    for old in reach:
        new = int(old2new[old])
        for k in _STAT_KEYS + ("node_depth",):
            np.testing.assert_array_equal(out[k][new], snap[k][old],
                                          err_msg=f"{k} old={old}")
