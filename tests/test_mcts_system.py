"""End-to-end behaviour of the Tree-Parallel MCTS system (paper Fig. 2)."""

import numpy as np
import pytest

import jax

from repro.core import TreeConfig, TreeParallelMCTS, RolloutBackend
from repro.distributed.fault import BSPFaultPolicy, HeartbeatMonitor
from repro.envs import BanditTreeEnv, GomokuEnv, PongLiteEnv
from repro.envs.policy_net import NNSimBackend, init_params


def test_pong_step_and_flush():
    env = PongLiteEnv()
    cfg = TreeConfig(X=96, F=6, D=9)
    m = TreeParallelMCTS(cfg, env, RolloutBackend(env, max_steps=30, seed=1),
                         p=8, executor="faithful")
    a, r, term = m.run_step(max_supersteps=20)
    assert 0 <= a < 6
    assert int(np.asarray(m.tree.size)) == 1  # flushed
    assert m.st.valid[0] and not m.st.valid[1:].any()


def test_mcts_beats_random_on_pong():
    """System-level sanity: planned actions keep the rally alive longer
    than uniform-random actions."""
    def play(policy, seed):
        env = PongLiteEnv(max_t=120)
        s = env.initial_state(seed)
        rng = np.random.RandomState(seed)
        total = 0.0
        for _ in range(120):
            if env.num_actions(s) == 0:
                break
            if policy == "random":
                a = int(rng.randint(6))
            else:
                cfg = TreeConfig(X=48, F=6, D=6)
                m = TreeParallelMCTS(
                    cfg, env, RolloutBackend(env, max_steps=25, seed=7),
                    p=8, executor="faithful")
                m.root_state = s
                m.st.flush(s)
                m.tree = m.exec.init(env.num_actions(s))
                for _ in range(6):
                    m.superstep()
                a = m.exec.best_action(m.tree)
            s, r, term = env.step(s, a)
            total += r
            if term:
                break
        return total

    mcts_score = np.mean([play("mcts", s) for s in range(3)])
    rand_score = np.mean([play("random", s) for s in range(3)])
    assert mcts_score > rand_score


def test_gomoku_nn_system_runs():
    env = GomokuEnv()
    cfg = TreeConfig(X=256, F=36, D=5, beta=5.0, score_fn="puct",
                     leaf_mode="unexpanded", expand_all=True)
    backend = NNSimBackend(env, init_params(jax.random.PRNGKey(0)))
    m = TreeParallelMCTS(cfg, env, backend, p=8, executor="faithful",
                         alternating_signs=True)
    for _ in range(4):
        m.superstep()
    snap = m.exec.snapshot(m.tree)
    assert int(snap["size"]) > 1
    assert np.all(snap["edge_VL"] == 0)


def test_gomoku_blocks_immediate_win():
    """Tactical sanity: with a 3-in-row on the board, MCTS (rollout
    backend) finds the winning move."""
    from repro.envs.gomoku import GomokuRolloutBackend
    env = GomokuEnv()
    s = env.initial_state()
    # X plays 3 in a row on row 0 (cols 0..2); O responds far away
    for cell_x, cell_o in [(0, 30), (1, 31), (2, 32)]:
        legal = env.legal_cells(s)
        s, _, _ = env.step(s, int(np.where(legal == cell_x)[0][0]))
        legal = env.legal_cells(s)
        s, _, _ = env.step(s, int(np.where(legal == cell_o)[0][0]))
    # X to move: cell 3 completes 4-in-row
    cfg = TreeConfig(X=512, F=36, D=4)
    m = TreeParallelMCTS(cfg, env, GomokuRolloutBackend(env, seed=0), p=8,
                         executor="faithful", alternating_signs=True)
    m.root_state = s
    m.st.flush(s)
    m.tree = m.exec.init(env.num_actions(s))
    for _ in range(12):
        m.superstep()
    a = m.exec.best_action(m.tree)
    winning_cell = int(env.legal_cells(s)[a])
    assert winning_cell == 3


@pytest.mark.parametrize("executor", ["reference", "faithful"])
def test_straggler_masked_superstep(executor):
    """Fault tolerance end to end: random workers miss the barrier every
    superstep; their backups are VL-recovery-only.  The tree must stay
    quiescent (VL == 0, O == 0), bit-equal across executors, and dropped
    workers must contribute no visits."""
    env = BanditTreeEnv(fanout=4, terminal_depth=8)
    cfg = TreeConfig(X=128, F=4, D=5)
    rngs = {}

    def injector_for(seed):
        rng = np.random.RandomState(seed)
        return lambda p: rng.rand(p) > 0.3   # ~30% stragglers

    def run(ex):
        m = TreeParallelMCTS(cfg, env, RolloutBackend(env, max_steps=8, seed=7),
                             p=8, executor=ex, seed=3)
        inj = injector_for(99)
        for _ in range(5):
            m.superstep(fault_injector=inj)
        return m.exec.snapshot(m.tree)

    a, b = run("reference"), run(executor)
    for k in a:
        if k == "log_table":
            continue
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert np.all(a["edge_VL"] == 0)
    assert np.all(a["node_O"] == 0)
    # visits strictly fewer than the fault-free run
    m_ok = TreeParallelMCTS(cfg, env, RolloutBackend(env, max_steps=8, seed=7),
                            p=8, executor="faithful", seed=3)
    for _ in range(5):
        m_ok.superstep()
    full = m_ok.exec.snapshot(m_ok.tree)
    assert a["node_N"][0] < full["node_N"][0]


def test_fault_policy_quorum():
    pol = BSPFaultPolicy(p=8, quorum=0.75)
    done = np.array([1, 1, 1, 1, 1, 0, 0, 0], bool)
    ok, mask = pol.commit_mask(done)
    assert not ok
    done[5] = True
    ok, mask = pol.commit_mask(done)
    assert ok and mask.sum() == 6
    vals, dropped = pol.masked_values(np.ones(8, np.float32), mask)
    assert vals[~mask].sum() == 0 and dropped.sum() == 2


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(n_workers=4, timeout_s=1.0)
    for w in range(4):
        hb.beat(w, now=100.0)
    alive = hb.sweep(now=100.5)
    assert alive.all()
    hb.beat(2, now=101.4)
    alive = hb.sweep(now=101.6)
    assert alive[2] and not alive[0]


def test_subtree_reuse_flush():
    """Beyond-paper re-rooting flush: statistics under the chosen action
    survive the step; invariants hold; ST entries are compacted."""
    env = BanditTreeEnv(fanout=4, terminal_depth=10)
    cfg = TreeConfig(X=256, F=4, D=6)
    m = TreeParallelMCTS(cfg, env, RolloutBackend(env, max_steps=12, seed=1),
                         p=8, executor="faithful")
    for _ in range(6):
        m.superstep()
    pre = m.exec.snapshot(m.tree)
    a = m.exec.best_action(m.tree)
    kept_child = int(pre["child"][int(pre["root"]), a])
    kept_n = int(pre["node_N"][kept_child])
    act, _, _ = m.run_step(max_supersteps=0, reuse_subtree=True)
    post = m.exec.snapshot(m.tree)
    assert act == a
    assert int(post["size"]) > 1                      # subtree survived
    assert int(post["node_N"][0]) == kept_n           # stats preserved
    assert np.all(post["edge_VL"] == 0) and np.all(post["node_O"] == 0)
    # child links are self-consistent and ST rows valid for all nodes
    size = int(post["size"])
    ids = post["child"][post["child"] >= 0]
    assert ids.max(initial=0) < size
    assert m.st.valid[:size].all()
    # the system keeps running correctly after re-rooting
    m.superstep()
    snap = m.exec.snapshot(m.tree)
    assert np.all(snap["edge_VL"] == 0)


def test_state_table_traffic_accounting():
    """ST sizes match the paper: 256 B/state (Pong), 432 B (Gomoku)."""
    from repro.core.state_table import StateTable
    st_p = StateTable(16, PongLiteEnv.state_shape, np.float32)
    st_g = StateTable(16, GomokuEnv.state_shape, np.float32)
    assert st_p.state_bytes == 256
    assert st_g.state_bytes == 432
