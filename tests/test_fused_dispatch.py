"""Fused K-superstep device dispatch — unit-level contracts.

The service-level bit-identity legs live in test_executor_matrix (the
fused runs against the sequential numpy oracle).  This file pins the
pieces those legs rest on:

  * the device env/sim twins are BIT-equal to their host twins — the
    splitmix hash emulated on (hi, lo) uint32 pairs, the transition
    function, and the value function whose op sequence is chosen so
    XLA's simplifier cannot rewrite it (no division by a non-power-of-2
    constant, no FMA-contractable multiply-then-subtract);
  * the capability probes gate the fused path exactly;
  * the fused program lowers as ONE compiled XLA program — including,
    on the pallas leg, with the kernels' INTERPRET flag off (the
    deployment configuration), compile-only so no TPU is needed.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import TreeConfig  # noqa: E402
from repro.core.fused import ESCAPE_NAMES, _fused_program  # noqa: E402
from repro.core.tree import init_arena  # noqa: E402
from repro.envs import BanditTreeEnv, BanditValueBackend  # noqa: E402
from repro.envs.bandit_tree import _hash_batch  # noqa: E402
from repro.envs.device import (  # noqa: E402
    has_device_env, has_device_sim, hash24_device, resolvable_device,
)

RNG = np.random.RandomState(7)


# ---------------------------------------------------------------------------
# device twins == host twins, bit for bit
# ---------------------------------------------------------------------------

def test_hash24_device_matches_numpy_hash():
    """The (hi, lo) uint32 emulation of the splitmix mix equals the
    numpy uint64 twin element-for-element over the whole input domain
    the env produces (24-bit hashes, small action codes)."""
    h = RNG.randint(0, 1 << 24, size=4096).astype(np.int64)
    for a in (0, 1, 5, 999, 4242, 7777, 12345):
        want = _hash_batch(h, a)
        got = np.asarray(jax.jit(hash24_device)(h, np.int64(a)))
        np.testing.assert_array_equal(got, want, err_msg=f"a={a}")


@pytest.mark.parametrize("varying", [False, True],
                         ids=["fixed-fanout", "varying-fanout"])
def test_step_device_matches_step_batch(varying):
    """env.step_device is a bit-exact twin of step_batch on every field
    the fused loop consumes (depth, hash, terminal, n_actions) — no
    rewards on device by contract."""
    env = BanditTreeEnv(fanout=4, terminal_depth=6, varying_fanout=varying)
    states = np.stack([env.initial_state(s) for s in range(64)])
    step_dev = jax.jit(env.step_device)
    for _ in range(6):   # walk to (past) terminal depth
        na = env.num_actions_batch(states)
        live = na > 0
        a = np.where(live, RNG.randint(0, np.maximum(na, 1)), 0)
        want_s, _, want_t = env.step_batch(states[live], a[live])
        got_s, got_t = step_dev(jnp.asarray(states), jnp.asarray(a))
        np.testing.assert_array_equal(np.asarray(got_s)[live], want_s)
        np.testing.assert_array_equal(np.asarray(got_t)[live], want_t)
        np.testing.assert_array_equal(
            np.asarray(jax.jit(env.num_actions_device)(got_s))[live],
            env.num_actions_batch(want_s))
        states = np.array(got_s)
        states[~live] = 0   # parked rows: keep the walk total


def test_evaluate_device_matches_host_bitwise():
    """The jitted value twin equals the host evaluate() BITWISE — the op
    sequence survives XLA's div-to-reciprocal rewrite and CPU FMA
    contraction (regression for both, found the hard way)."""
    env = BanditTreeEnv(fanout=4, terminal_depth=8)
    sim = BanditValueBackend()
    states = np.stack([env.initial_state(s) for s in range(2048)])
    want, _ = sim.evaluate(states)
    got = np.asarray(jax.jit(sim.evaluate_device)(jnp.asarray(states)))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# capability probes
# ---------------------------------------------------------------------------

def test_capability_probes():
    env, sim = BanditTreeEnv(fanout=4), BanditValueBackend()
    assert has_device_env(env) and has_device_sim(sim)

    class HostOnlyEnv:
        def step(self, s, a): ...

    class HostOnlySim:
        def evaluate(self, s): ...

    assert not has_device_env(HostOnlyEnv())
    assert not has_device_sim(HostOnlySim())
    # no resolvable_device hook -> everything resolvable
    ok = resolvable_device(env, jnp.zeros((3, 8)), jnp.zeros(3, jnp.int32))
    assert np.asarray(ok).all()
    assert set(ESCAPE_NAMES.values()) == {"ran_k", "commit", "expand"}


# ---------------------------------------------------------------------------
# the fused program is ONE compiled XLA program
# ---------------------------------------------------------------------------

def _lower(variant, cfg):
    env = BanditTreeEnv(fanout=4, terminal_depth=10)
    sim = BanditValueBackend()
    Ge, p = 2, 3
    arena = init_arena(cfg, Ge)
    states = jnp.zeros((Ge, cfg.X) + env.state_shape, jnp.float32)
    return _fused_program.lower(
        cfg, variant, p, 4, env, sim, False,
        arena, states, jnp.ones(Ge, bool), jnp.full(Ge, 5, jnp.int32))


def test_fused_program_lowers_single_program_faithful():
    """K supersteps of select/insert/expand/simulate/finalize/backup
    lower (and compile) as one XLA program with a single while loop —
    the dispatch-boundary crossing the tentpole removes."""
    lowered = _lower("faithful", TreeConfig(X=72, F=4, D=6))
    text = lowered.as_text()
    assert "while" in text           # the fused superstep loop
    lowered.compile()                # compiles end-to-end on this host


def test_fused_program_lowers_with_interpret_off_pallas():
    """Compile-only deployment check: the pallas leg must still trace
    and lower with kernels.ops.INTERPRET=False (real kernel lowering,
    not the interpreter).  Skips where this backend cannot lower Pallas
    kernels at all (CPU-only jaxlib builds)."""
    from repro.kernels import ops as kops

    old = kops.INTERPRET
    kops.INTERPRET = False
    try:
        # fresh cfg -> fresh cache key -> really re-traces with the flag off
        lowered = _lower("pallas", TreeConfig(X=80, F=4, D=6))
    except Exception as e:  # noqa: BLE001 — backend-dependent lowering gap
        pytest.skip(f"pallas kernels do not lower on this backend: {e}")
    finally:
        kops.INTERPRET = old
    assert "while" in lowered.as_text()
