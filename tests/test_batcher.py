"""ContinuousBatcher lifecycle under a live request stream: admission
mid-decode, eviction causes (EOS / max-tokens / max-seq), backpressure
bounds, deterministic replay (tokens AND logprobs), telemetry gauges.

Complements tests/test_serving.py (which pins ragged-batch == reference
numerics); this file pins the SERVING behaviours the sim layer's
LMContinuationBackend and the bench's service_nn_backend_lm_* rows
build on.
"""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.obs.metrics import MetricsRegistry
from repro.serving import ContinuousBatcher, Request

from test_serving import greedy_reference


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(cfg, n, n_new=4, seed=0, **kw):
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    prompt=rng.randint(1, cfg.vocab, size=3 + i % 4)
                    .astype(np.int32),
                    max_new_tokens=n_new, **kw) for i in range(n)]


def test_admission_mid_decode_matches_reference(model):
    """A request admitted while other slots are mid-decode gets the same
    tokens as an isolated greedy decode of its prompt."""
    cfg, params = model
    b = ContinuousBatcher(cfg, params, pool_size=2, max_seq=64, impl="naive")
    early = _reqs(cfg, 2, n_new=6, seed=1)
    for r in early:
        b.submit(r)
    for _ in range(3):                      # pool is mid-decode...
        b.step()
    late = Request(uid=99, prompt=np.array([4, 7, 11], np.int32),
                   max_new_tokens=6)
    b.submit(late)                          # ...when this admits
    done = b.run(max_steps=100)
    assert {r.uid for r in done} == {0, 1, 99}
    for r in done:
        assert r.tokens == greedy_reference(cfg, params, r.prompt, 6), r.uid


def test_eviction_reasons(model):
    """EOS evicts early, max_tokens evicts on budget, a near-full cache
    evicts on max_seq — and each bumps its own labelled counter."""
    cfg, params = model
    reg = MetricsRegistry()
    b = ContinuousBatcher(cfg, params, pool_size=3, max_seq=64, impl="naive",
                          metrics=reg)
    prompt = np.array([1, 2, 3], np.int32)
    budget = Request(uid=0, prompt=prompt, max_new_tokens=3)
    # pick the EOS id so it triggers: the 2nd greedy token of this prompt
    eos = greedy_reference(cfg, params, prompt, 2)[1]
    eosy = Request(uid=1, prompt=prompt, max_new_tokens=50, eos_id=eos)
    b.submit(budget)
    b.submit(eosy)
    done = b.run(max_steps=100)
    assert len(done) == 2
    assert len(budget.tokens) == 3
    assert eosy.tokens[-1] == eos and len(eosy.tokens) == 2
    assert reg.get("serving_evictions_total", reason="max_tokens").value == 1
    assert reg.get("serving_evictions_total", reason="eos").value == 1

    tight = ContinuousBatcher(cfg, params, pool_size=1, max_seq=8,
                              impl="naive", metrics=reg)
    tight.submit(Request(uid=2, prompt=prompt, max_new_tokens=50))
    (walled,) = tight.run(max_steps=100)
    assert walled.uid == 2 and len(walled.tokens) < 50
    assert reg.get("serving_evictions_total", reason="max_seq").value == 1
    assert reg.get("serving_completed_total").value == 3


def test_backpressure_bounds_queue_without_drops(model):
    """max_pending makes the submitter pay service time: the waiting
    queue never exceeds the bound, yet every request completes with the
    same tokens as the unbounded run."""
    cfg, params = model
    free = ContinuousBatcher(cfg, params, pool_size=2, max_seq=64,
                             impl="naive")
    for r in _reqs(cfg, 8, seed=2):
        free.submit(r)
    ref = {r.uid: r.tokens for r in free.run(max_steps=300)}
    assert len(ref) == 8

    reg = MetricsRegistry()
    b = ContinuousBatcher(cfg, params, pool_size=2, max_seq=64, impl="naive",
                          max_pending=2, metrics=reg)
    peak = 0
    for r in _reqs(cfg, 8, seed=2):
        b.submit(r)
        peak = max(peak, len(b.queue))
        assert len(b.queue) <= 2
    done = b.run(max_steps=300)
    assert {r.uid: r.tokens for r in done} == ref       # nothing dropped
    assert reg.get("serving_admitted_total").value == 8
    assert reg.get("serving_queue_depth").value == 0


def test_deterministic_replay_tokens_and_logprobs(model):
    """Same request stream twice -> identical tokens and bit-identical
    logprobs (the LM value signal the sim layer scores with)."""
    cfg, params = model

    def run():
        b = ContinuousBatcher(cfg, params, pool_size=2, max_seq=64,
                              impl="naive", record_logprobs=True)
        for r in _reqs(cfg, 5, seed=3):
            b.submit(r)
        return b.run(max_steps=200)

    one, two = run(), run()
    assert [r.uid for r in one] == [r.uid for r in two]
    for a, b_ in zip(one, two):
        assert a.tokens == b_.tokens
        assert len(a.logprobs) == len(a.tokens)
        assert a.logprobs == b_.logprobs
        assert all(np.isfinite(lp) and lp <= 0.0 for lp in a.logprobs)


def test_occupancy_and_queue_gauges(model):
    cfg, params = model
    reg = MetricsRegistry()
    b = ContinuousBatcher(cfg, params, pool_size=2, max_seq=64, impl="naive",
                          metrics=reg)
    for r in _reqs(cfg, 3, n_new=3, seed=4):
        b.submit(r)
    assert reg.get("serving_queue_depth").value == 3
    b.step()                                # admits 2 of 3 into the pool
    assert reg.get("serving_pool_occupancy").value == 1.0
    assert reg.get("serving_queue_depth").value == 1
    b.run(max_steps=100)
    assert reg.get("serving_pool_occupancy").value == 0.0
    assert reg.get("serving_queue_depth").value == 0
