"""Cross-executor differential harness.

One randomized service schedule — oversubscribed admissions, staggered
evictions, multi-move requests advancing via reroot — is replayed through
EVERY in-tree executor x {masked, compacted} x {loop, vector, pool}
expansion and compared per slot, bit for bit.

Two claims, split by executor class:

  * bit-compatible executors (reference / faithful / pallas) must
    reproduce the sequential numpy oracle exactly under every combo;
  * relaxed/wavefront change intra-superstep semantics BY DESIGN (they
    diverge from the oracle), but compaction and the expansion engine are
    still required to be pure transforms: every combo must equal that
    executor's own masked/loop run bit for bit.

The executor axis is EXECUTOR_NAMES from core.executor, so a newly
registered executor is enrolled in the whole matrix automatically — a new
name shows up here (and must declare itself in BIT_COMPATIBLE if it
claims oracle equality).

The multi-arena frontend rides the same harness: the schedule replayed
through ServiceFrontend (config-carrying requests, persistent compaction
sessions) must equal each executor's direct SearchService run — the
frontend/pool split and session write-back deferral are pure
re-layerings, never semantic changes.

So does the SearchClient redesign: the same schedule through the handle
API (round-robin policy — the historical cadence) must round-trip bit-
identically on every executor, and the cross-pool fused evaluate path
(weighted-queue-depth gang ticks batching a >= 3-config mix into ONE
SimulationBackend.evaluate per tick) must equal dedicated single-config
runs per request while its fused batches strictly exceed any single
pool's share.
"""

import numpy as np
import pytest

from repro.core import TreeConfig
from repro.core.executor import EXECUTOR_NAMES
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import (
    SearchClient, SearchRequest, SearchService, ServiceFrontend,
)

CFG = TreeConfig(X=160, F=4, D=6)
ENV = BanditTreeEnv(fanout=4, terminal_depth=10)
G, P = 3, 4

# Executors whose per-slot arithmetic is bit-compatible with the
# sequential numpy oracle.  relaxed/wavefront are intentionally absent
# (documented intra-superstep semantics change); everything else MUST be
# listed — a new executor that skips this list still gets the
# self-consistency matrix but not the oracle gate.
BIT_COMPATIBLE = ("reference", "faithful", "pallas")

ORACLE = ("reference", 0.0, "loop")  # the paper's CPU-only master process


def _schedule(seed=42, n=6):
    """Randomized but reproducible request mix: oversubscribed (n > G),
    staggered budgets (uneven eviction), multi-move (reroot path)."""
    rng = np.random.RandomState(seed)
    reqs = [dict(uid=i, seed=int(rng.randint(100)),
                 budget=int(rng.randint(2, 5)),
                 moves=int(rng.randint(1, 3)),
                 keep_tree=True) for i in range(n)]
    # a long tail: the last request outlives the rest, so occupancy
    # decays through 2/G and 1/G and the compacted path really runs
    reqs[-1].update(budget=6, moves=2)
    return reqs


_SCHEDULE = _schedule()
_RESULTS: dict = {}


def _run(executor: str, compact: float, expansion: str):
    key = (executor, compact, expansion)
    if key in _RESULTS:
        return _RESULTS[key]
    svc = SearchService(CFG, ENV, BanditValueBackend(), G=G, p=P,
                        executor=executor, compact_threshold=compact,
                        expansion=expansion)
    try:
        for kw in _SCHEDULE:
            svc.submit(SearchRequest(**kw))
        done = {r.uid: r for r in svc.run()}
    finally:
        svc.close()
    assert sorted(done) == [kw["uid"] for kw in _SCHEDULE]
    if compact > 0.0:
        # the combo must actually exercise the compacted path: the tail
        # of the schedule drains occupancy below the threshold
        assert svc.stats.compacted_supersteps > 0
    _RESULTS[key] = (done, svc.stats.supersteps)
    return _RESULTS[key]


def _assert_identical(got, want, label):
    done_a, steps_a = got
    done_b, steps_b = want
    assert steps_a == steps_b, f"{label}: superstep counts diverged"
    for uid in want[0]:
        a, b = done_a[uid], done_b[uid]
        assert a.actions == b.actions, f"{label} uid={uid}"
        assert a.rewards == b.rewards, f"{label} uid={uid}"
        assert a.supersteps == b.supersteps, f"{label} uid={uid}"
        for va, vb in zip(a.visit_counts, b.visit_counts):
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{label} uid={uid}")
        for k in b.tree_snapshot:
            np.testing.assert_array_equal(
                a.tree_snapshot[k], b.tree_snapshot[k],
                err_msg=f"{label} uid={uid} field={k}")


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
@pytest.mark.parametrize("compact", [0.0, 0.7], ids=["masked", "compacted"])
@pytest.mark.parametrize("expansion", ["loop", "vector"])
def test_matrix_self_consistency(executor, compact, expansion):
    """Compaction and the expansion engine are pure transforms for every
    executor: each combo equals the executor's masked/loop run."""
    _assert_identical(
        _run(executor, compact, expansion),
        _run(executor, 0.0, "loop"),
        f"{executor}/{'compacted' if compact else 'masked'}/{expansion}")


@pytest.mark.parametrize("executor", [e for e in EXECUTOR_NAMES
                                      if e in BIT_COMPATIBLE])
@pytest.mark.parametrize("compact", [0.0, 0.7], ids=["masked", "compacted"])
@pytest.mark.parametrize("expansion", ["loop", "vector"])
def test_matrix_matches_sequential_oracle(executor, compact, expansion):
    """Acceptance: every bit-compatible executor x compaction x expansion
    combo reproduces the sequential numpy oracle per slot, bit for bit."""
    _assert_identical(
        _run(executor, compact, expansion),
        _run(*ORACLE),
        f"{executor} vs oracle")


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
def test_frontend_path_matches_direct_service(executor):
    """The frontend/pool split is a pure re-layering: the same schedule
    routed through ServiceFrontend (requests carrying their TreeConfig,
    persistent compaction sessions on) equals the executor's own direct
    SearchService masked/loop run — and therefore, transitively, the
    sequential oracle for every BIT_COMPATIBLE executor."""
    fe = ServiceFrontend(ENV, BanditValueBackend(), G=G, p=P,
                         executor=executor, compact_threshold=0.7,
                         persistent_compaction=True)
    try:
        for kw in _SCHEDULE:
            fe.submit(SearchRequest(cfg=CFG, **kw))
        done = {r.uid: r for r in fe.run()}
        stats = fe.stats
    finally:
        fe.close()
    assert len(fe.pools) == 1   # one config -> one bucket
    # the drain tail compacts, and sessions persist across supersteps
    # instead of re-gathering each one
    assert stats.compacted_supersteps > 0
    assert stats.session_gathers < stats.compacted_supersteps
    assert stats.session_reuses > 0
    _assert_identical((done, stats.supersteps), _run(executor, 0.0, "loop"),
                      f"frontend/{executor}")


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
def test_client_round_trip_matches_direct_service(executor):
    """Acceptance: the SearchClient handle API (round-robin policy) is a
    pure re-surfacing — the matrix schedule submitted through handles and
    drained with result() equals the executor's own direct SearchService
    masked/loop run, superstep counts included."""
    cl = SearchClient(ENV, BanditValueBackend(), G=G, p=P,
                      executor=executor, default_cfg=CFG,
                      compact_threshold=0.7, persistent_compaction=True)
    try:
        handles = [cl.submit(SearchRequest(cfg=CFG, **kw))
                   for kw in _SCHEDULE]
        done = {h.uid: h.result() for h in handles}
        stats = cl.stats
    finally:
        cl.close()
    assert all(h.status() == "done" for h in handles)
    # the compacted drain tail still runs through persistent sessions
    assert stats.compacted_supersteps > 0
    assert stats.session_gathers < stats.compacted_supersteps
    _assert_identical((done, stats.supersteps), _run(executor, 0.0, "loop"),
                      f"client/{executor}")


# three shape classes for the cross-pool fusion acceptance: same fanout
# (the env fixes F), different arena/depth classes
XPOOL_CFGS = (CFG, TreeConfig(X=128, F=4, D=5), TreeConfig(X=96, F=4, D=4))


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
def test_client_xpool_fused_matches_dedicated_services(executor):
    """Acceptance: cross-pool fused evaluate (ONE SimulationBackend
    .evaluate spanning every advancing pool of a 3-config heterogeneous
    mix) is bit-identical per request to dedicated single-config runs,
    and the fused batch strictly exceeds the largest single-pool share."""
    reqs = [dict(uid=i, seed=50 + i, budget=3, moves=1 + i % 2,
                 keep_tree=True) for i in range(6)]
    cl = SearchClient(ENV, BanditValueBackend(), G=2, p=P,
                      executor=executor, policy="weighted-queue-depth")
    try:
        handles = [cl.submit(SearchRequest(cfg=XPOOL_CFGS[i % 3], **kw))
                   for i, kw in enumerate(reqs)]
        done = {h.uid: h.result() for h in handles}
        assert cl.core.xpool_batches > 0
        assert cl.core.xpool_rows_max > cl.core.xpool_pool_rows_max > 0
    finally:
        cl.close()
    for i, kw in enumerate(reqs):
        svc = SearchService(XPOOL_CFGS[i % 3], ENV, BanditValueBackend(),
                            G=1, p=P, executor=executor)
        try:
            svc.submit(SearchRequest(**kw))
            (want,) = svc.run()
        finally:
            svc.close()
        got, label = done[kw["uid"]], f"xpool/{executor} uid={kw['uid']}"
        assert got.actions == want.actions, label
        assert got.rewards == want.rewards, label
        assert got.supersteps == want.supersteps, label
        for va, vb in zip(got.visit_counts, want.visit_counts):
            np.testing.assert_array_equal(va, vb, err_msg=label)
        for k in want.tree_snapshot:
            np.testing.assert_array_equal(
                got.tree_snapshot[k], want.tree_snapshot[k],
                err_msg=f"{label} field={k}")


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
def test_traced_run_bit_identical_to_untraced(executor):
    """Acceptance: tracing + metrics never change WHAT is computed — the
    matrix schedule with a live Tracer/MetricsRegistry (device-fencing
    spans included) equals the executor's own untraced masked/loop run,
    and the recorded trace covers the superstep phases and round-trips
    through json."""
    import json

    from repro.obs import MetricsRegistry, Tracer

    cl = SearchClient(ENV, BanditValueBackend(), G=G, p=P,
                      executor=executor, default_cfg=CFG,
                      compact_threshold=0.7, persistent_compaction=True,
                      trace=Tracer(), metrics=MetricsRegistry())
    try:
        handles = [cl.submit(SearchRequest(cfg=CFG, **kw))
                   for kw in _SCHEDULE]
        done = {h.uid: h.result() for h in handles}
        stats = cl.stats
        trace = cl.trace_export()
        metrics = cl.metrics()
    finally:
        cl.close()
    _assert_identical((done, stats.supersteps), _run(executor, 0.0, "loop"),
                      f"traced/{executor}")
    names = {e["name"] for e in trace["traceEvents"]}
    for phase in ("superstep", "select", "expand", "simulate", "backup",
                  "compact-gather", "compact-scatter"):
        assert phase in names, f"{executor}: phase {phase!r} missing"
    json.loads(json.dumps(trace))        # valid Chrome-trace JSON
    assert "service_supersteps_total" in metrics


def test_pool_expansion_matches_oracle():
    """The process-pool fallback is schedule- and bit-identical too (one
    combo: spawning pools under every executor adds nothing)."""
    _assert_identical(_run("faithful", 0.0, "pool"), _run(*ORACLE),
                      "faithful/pool vs oracle")


def test_expand_all_vector_matches_loop():
    """Gomoku-style expand-all + PUCT priors through the batched engine:
    the flattened (leaf x action) rows must reproduce the loop exactly."""
    jax = pytest.importorskip("jax")
    from repro.envs import GomokuEnv
    from repro.envs.policy_net import NNSimBackend, init_params

    env = GomokuEnv()
    cfg = TreeConfig(X=128, F=36, D=5, beta=5.0, score_fn="puct",
                     leaf_mode="unexpanded", expand_all=True)
    backend = NNSimBackend(env, init_params(jax.random.PRNGKey(0)))

    def go(expansion):
        svc = SearchService(cfg, env, backend, G=2, p=4, executor="faithful",
                            alternating_signs=True, expansion=expansion)
        try:
            for i in range(2):
                svc.submit(SearchRequest(uid=i, seed=i, budget=3,
                                         keep_tree=True))
            return {r.uid: r for r in svc.run()}, svc.stats.supersteps
        finally:
            svc.close()

    _assert_identical(go("vector"), go("loop"), "expand-all vector")


# ---------------------------------------------------------------------------
# fused K-superstep device dispatch (repro.core.fused)
# ---------------------------------------------------------------------------

# executors with a fused run_supersteps leg (reference keeps the
# phase-by-phase oracle on purpose)
FUSED_EXECUTORS = ("faithful", "pallas")


@pytest.mark.parametrize("executor", FUSED_EXECUTORS)
@pytest.mark.parametrize("k", [1, 4], ids=["k1", "k4"])
@pytest.mark.parametrize("compact", [0.0, 0.7], ids=["masked", "compacted"])
def test_fused_dispatch_matches_oracle(executor, k, compact):
    """Acceptance: the fused K-superstep device dispatch is grouping-
    independent — the matrix schedule with supersteps_per_dispatch=K
    equals the sequential numpy oracle per slot, bit for bit, on every
    fused-capable executor, masked and compacted.  K=1 keeps the classic
    phase-by-phase path (the degenerate case must not regress); K=4
    must actually run fused dispatches and hit the move-commit escape
    (the schedule's budgets are all < 2K)."""
    svc = SearchService(CFG, ENV, BanditValueBackend(), G=G, p=P,
                        executor=executor, compact_threshold=compact,
                        supersteps_per_dispatch=k)
    try:
        for kw in _SCHEDULE:
            svc.submit(SearchRequest(**kw))
        done = {r.uid: r for r in svc.run()}
        stats = svc.stats
    finally:
        svc.close()
    _assert_identical((done, stats.supersteps), _run(*ORACLE),
                      f"fused/{executor}/K={k}")
    if k > 1:
        assert stats.fused_dispatches > 0
        assert stats.fused_supersteps > 0
        assert stats.fused_escape_commit > 0      # commit edge exercised
        if compact > 0.0:
            assert stats.compacted_supersteps > 0  # fused on the sub-arena
    else:
        assert stats.fused_dispatches == 0        # K=1 is the classic path


class _PartialDeviceEnv(BanditTreeEnv):
    """Device twin that refuses transitions from depth >= 2 leaves: every
    deeper expansion forces the fused loop's post-insert escape to the
    host ExpansionEngine path."""

    def resolvable_device(self, states, actions):
        return states[..., 0] < 2


@pytest.mark.parametrize("executor", FUSED_EXECUTORS)
def test_fused_dispatch_expansion_escape_matches_oracle(executor):
    """Acceptance: the escape-at-expansion edge — a superstep whose
    expansion the device env twin cannot resolve exits the loop post-
    insert and completes through the ordinary host expansion path,
    still bit-identical to the oracle on the same env."""
    env = _PartialDeviceEnv(fanout=4, terminal_depth=10)

    def go(executor, k):
        svc = SearchService(CFG, env, BanditValueBackend(), G=G, p=P,
                            executor=executor, supersteps_per_dispatch=k)
        try:
            for kw in _SCHEDULE:
                svc.submit(SearchRequest(**kw))
            done = {r.uid: r for r in svc.run()}
            stats = svc.stats
        finally:
            svc.close()
        return (done, stats.supersteps), stats

    got, stats = go(executor, 4)
    want, _ = go("reference", 1)
    assert stats.fused_escape_expand > 0          # the edge really fired
    _assert_identical(got, want, f"fused-escape/{executor}")


def test_new_executors_must_enroll():
    """Guard: the matrix derives from EXECUTOR_NAMES, so this only fires
    if someone renames the constant away — the auto-enrolment contract."""
    assert set(BIT_COMPATIBLE) <= set(EXECUTOR_NAMES)
    assert {"reference", "faithful"} <= set(EXECUTOR_NAMES)


# ---------------------------------------------------------------------------
# D-sharded serving (core/sharded.py): placement is scheduling, never
# semantics.  Per-REQUEST fields are compared — pool-total dispatch
# counters legitimately differ at D > 1 (per-shard sums), but what any
# request computes may not.  On a 1-device host the shard->device map
# wraps (launch.mesh.serving_devices), so the partition logic runs
# everywhere; the CI leg with
# XLA_FLAGS=--xla_force_host_platform_device_count=4 puts each shard on
# its own device.
# ---------------------------------------------------------------------------

SHARD_G = 4                    # divisible by every D leg (G=3 above isn't)
_SHARD_BASE: dict = {}


def _run_sharded(executor, n_shards, k=1, compact=0.0):
    cl = SearchClient(ENV, BanditValueBackend(), G=SHARD_G, p=P,
                      executor=executor, default_cfg=CFG,
                      n_shards=n_shards, supersteps_per_dispatch=k,
                      compact_threshold=compact)
    try:
        handles = [cl.submit(SearchRequest(cfg=CFG, **kw))
                   for kw in _SCHEDULE]
        done = {h.uid: h.result() for h in handles}
        (pool,) = cl.core.pools.values()
        assert pool.n_shards == n_shards
        if n_shards > 1:
            assert getattr(pool.exec, "n_shards", 1) == n_shards
        if k > 1 and executor in FUSED_EXECUTORS:
            assert pool.stats.fused_dispatches > 0
        if compact > 0.0:
            assert pool.stats.compacted_supersteps > 0
    finally:
        cl.close()
    return done


def _shard_base(executor, k=1):
    """D=1 baseline per (executor, K), cached across the leg matrix."""
    key = (executor, k)
    if key not in _SHARD_BASE:
        _SHARD_BASE[key] = _run_sharded(executor, 1, k=k)
    return _SHARD_BASE[key]


def _assert_requests_identical(done_a, done_b, label):
    assert sorted(done_a) == sorted(done_b), label
    for uid in done_b:
        a, b = done_a[uid], done_b[uid]
        assert a.actions == b.actions, f"{label} uid={uid}"
        assert a.rewards == b.rewards, f"{label} uid={uid}"
        assert a.supersteps == b.supersteps, f"{label} uid={uid}"
        for va, vb in zip(a.visit_counts, b.visit_counts):
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{label} uid={uid}")
        for k in b.tree_snapshot:
            np.testing.assert_array_equal(
                a.tree_snapshot[k], b.tree_snapshot[k],
                err_msg=f"{label} uid={uid} field={k}")


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
@pytest.mark.parametrize("n_shards", [2, 4], ids=["d2", "d4"])
def test_sharded_serving_bit_identical(executor, n_shards):
    """Acceptance: the matrix schedule through a D-sharded arena (least-
    loaded placement across per-device shard arenas) returns bit-
    identical per-request results to the same client at n_shards=1, on
    every executor."""
    got = _run_sharded(executor, n_shards)
    _assert_requests_identical(got, _shard_base(executor),
                               f"shard/{executor}/D={n_shards}")


@pytest.mark.parametrize("executor", ["reference", "faithful"])
def test_sharded_compaction_bit_identical(executor):
    """The compaction transform composes with sharding: a D=2 run whose
    drain tail gathers per-shard dense sub-arenas (ShardedExecutor
    .gather_sub, one sub per device behind one session) still equals
    the executor's own D=1 masked run per request."""
    got = _run_sharded(executor, 2, compact=0.7)
    _assert_requests_identical(got, _shard_base(executor),
                               f"shard-compact/{executor}")


@pytest.mark.parametrize("executor", FUSED_EXECUTORS)
@pytest.mark.parametrize("n_shards", [2, 4], ids=["d2", "d4"])
def test_sharded_fused_dispatch_bit_identical(executor, n_shards):
    """Acceptance: per-shard fused K-superstep dispatches — each shard
    runs its own device program to its own escape — stay bit-identical
    per request to the D=1 fused run.  Commit boundaries are slot-
    local, so dispatch grouping (which only decides when the host
    gets control) never leaks into results."""
    got = _run_sharded(executor, n_shards, k=4)
    _assert_requests_identical(got, _shard_base(executor, k=4),
                               f"shard-fused/{executor}/D={n_shards}")


# ---------------------------------------------------------------------------
# overlap mode (service/pool.py GangSchedule): pipelined supersteps.
# Double-buffered gangs reschedule WHEN each slot's superstep runs — one
# gang's host expansion/simulation overlaps the next gang's device
# in-tree phases — but per-slot arithmetic is position-independent and
# gangs partition the slot axis, so every request's trajectory (actions,
# rewards, visit counts, per-request superstep count, final tree) must
# stay bit-identical to the lock-step run on the SAME executor.  The
# gang schedule is a pure function of (G, n_gangs, shard partition) and
# occupancy, so a replay is deterministic by construction.
# ---------------------------------------------------------------------------

def _run_overlap(executor, n_gangs=2, k=1, n_shards=1, overlap=True):
    """The matrix schedule through an overlap-mode client (same G/CFG as
    the sharded legs, so _shard_base supplies the lock-step oracle)."""
    cl = SearchClient(ENV, BanditValueBackend(), G=SHARD_G, p=P,
                      executor=executor, default_cfg=CFG,
                      overlap=overlap, n_gangs=n_gangs,
                      supersteps_per_dispatch=k, n_shards=n_shards)
    try:
        handles = [cl.submit(SearchRequest(cfg=CFG, **kw))
                   for kw in _SCHEDULE]
        done = {h.uid: h.result() for h in handles}
        (pool,) = cl.core.pools.values()
        if overlap:
            assert pool.overlap and pool.gangs.n_gangs == n_gangs
            # a drained pool may not hold a half-finished gang
            assert pool._inflight is None
            assert pool._inflight_fused is None
            if k > 1 and executor in FUSED_EXECUTORS:
                assert pool.stats.fused_dispatches > 0
    finally:
        cl.close()
    return done


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
def test_overlap_bit_identical_to_lockstep(executor):
    """Acceptance: overlap=True returns bit-identical per-request
    results to the same client at overlap=False, on EVERY executor —
    including relaxed/wavefront, whose intra-superstep semantics differ
    from the oracle but are still per-slot deterministic."""
    got = _run_overlap(executor)
    _assert_requests_identical(got, _shard_base(executor),
                               f"overlap/{executor}")


def test_overlap_gang_count_is_semantics_free():
    """n_gangs only re-phases the pipeline: a 3-gang (and 4-gang, i.e.
    one slot per gang at G=4) run equals the 2-gang and lock-step runs."""
    for n_gangs in (3, 4):
        _assert_requests_identical(
            _run_overlap("faithful", n_gangs=n_gangs),
            _shard_base("faithful"), f"overlap/faithful/gangs={n_gangs}")


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
def test_overlap_off_is_bit_identical_on_every_executor(executor):
    """Acceptance: the overlap refactor (insert_dev/insert_host split,
    submit/collect expansion, staged fused dispatch) left the default
    overlap=False path bit-identical — pinned explicitly per executor,
    not just via the legacy suites."""
    got = _run_overlap(executor, overlap=False)
    _assert_requests_identical(got, _shard_base(executor),
                               f"overlap-off/{executor}")


def test_overlap_deterministic_replay():
    """Acceptance: the gang schedule is fixed, so an overlap run is
    exactly reproducible — two fresh clients produce identical results
    AND identical per-request superstep counts (same interleaving)."""
    a = _run_overlap("faithful")
    b = _run_overlap("faithful")
    _assert_requests_identical(a, b, "overlap-replay")


@pytest.mark.parametrize("executor", ["reference", "faithful", "pallas"])
@pytest.mark.parametrize("n_shards", [1, 2], ids=["d1", "d2"])
def test_overlap_sharded_bit_identical(executor, n_shards):
    """Acceptance: overlap composes with D-sharding — gang masks
    partition WITHIN shard runs (gang_of interleaves slots round-robin
    inside each shard), so a D=2 overlap run equals the D=1 lock-step
    run per request.  The CI leg with
    XLA_FLAGS=--xla_force_host_platform_device_count=4 places the
    shards on real separate devices."""
    got = _run_overlap(executor, n_shards=n_shards)
    _assert_requests_identical(got, _shard_base(executor),
                               f"overlap-shard/{executor}/D={n_shards}")


@pytest.mark.parametrize("executor", FUSED_EXECUTORS)
def test_overlap_fused_dispatch_bit_identical(executor):
    """Acceptance: overlap composes with the fused K-superstep path —
    one gang's device programs run while the previous gang's collect /
    escape / accounting holds the host — and stays bit-identical to the
    lock-step fused run."""
    got = _run_overlap(executor, k=4)
    _assert_requests_identical(got, _shard_base(executor, k=4),
                               f"overlap-fused/{executor}")


def test_overlap_fused_sharded_composes():
    """All three axes at once: D=2 shards x K=4 fused dispatch x 2-gang
    overlap still equals the plain D=1 K=4 run per request."""
    got = _run_overlap("faithful", k=4, n_shards=2)
    _assert_requests_identical(got, _shard_base("faithful", k=4),
                               "overlap-fused-shard/faithful")


def test_overlap_trace_exposes_gang_tracks():
    """The obs satellite: an overlap run with tracing on emits per-gang
    timeline tracks and the busy-ratio/efficiency overlap metrics, and
    tracing still never changes WHAT is computed."""
    from repro.obs import MetricsRegistry, Tracer

    cl = SearchClient(ENV, BanditValueBackend(), G=SHARD_G, p=P,
                      executor="faithful", default_cfg=CFG,
                      overlap=True, expansion="vector", trace=Tracer(),
                      metrics=MetricsRegistry())
    try:
        handles = [cl.submit(SearchRequest(cfg=CFG, **kw))
                   for kw in _SCHEDULE]
        done = {h.uid: h.result() for h in handles}
        trace = cl.trace_export()
        metrics = cl.metrics()
    finally:
        cl.close()
    _assert_requests_identical(done, _shard_base("faithful"),
                               "overlap-traced/faithful")
    tracks = {e["args"]["name"] for e in trace["traceEvents"]
              if e.get("name") == "thread_name"}
    gang_tracks = {t for t in tracks if ":gang" in t}
    assert len(gang_tracks) >= 2, tracks   # one per pipelined gang
    names = {e["name"] for e in trace["traceEvents"]}
    # the async split renames the expansion phase into its two halves
    assert {"superstep", "select", "expand-submit", "expand-collect",
            "simulate"} <= names
    assert "service_overlap_busy_ratio" in metrics
    assert "service_overlap_efficiency" in metrics


# ---------------------------------------------------------------------------
# NN-backed differential leg (repro.sim): the served DNN simulation path
# — SimServer microbatching + transposition cache — through SearchClient
# on every executor.  SimServer pads every microbatch to a fixed shape,
# so per-row inference is batch-composition independent; therefore
# (a) cache-on must equal cache-off bit for bit on EVERY executor (the
# cache only changes which rows reach the forward), and (b) the
# BIT_COMPATIBLE executors must agree with reference under the NN
# backend exactly as they do under the bandit oracle.
# ---------------------------------------------------------------------------

NN_CFG = TreeConfig(X=128, F=36, D=5, beta=5.0, score_fn="puct",
                    leaf_mode="unexpanded", expand_all=True)
NN_SCHEDULE = [dict(uid=i, seed=i, budget=2, moves=1 + i % 2,
                    keep_tree=True) for i in range(3)]
_NN_RESULTS: dict = {}
_NN_PARAMS: list = []


def _run_nn(executor: str, cache: bool):
    key = (executor, cache)
    if key in _NN_RESULTS:
        return _NN_RESULTS[key]
    jax = pytest.importorskip("jax")
    from repro.envs import GomokuEnv
    from repro.envs.policy_net import NNSimBackend, init_params
    from repro.sim import CachedSimBackend, SimServer

    if not _NN_PARAMS:
        _NN_PARAMS.append(init_params(jax.random.PRNGKey(0)))
    from repro.obs import MetricsRegistry

    env = GomokuEnv()
    reg = MetricsRegistry()
    sim = SimServer(NNSimBackend(env, _NN_PARAMS[0]), max_batch=16)
    if cache:
        sim = CachedSimBackend(sim, capacity=512, metrics=reg)
    cl = SearchClient(env, sim_backend=sim, G=2, p=P, executor=executor,
                      default_cfg=NN_CFG, alternating_signs=True)
    try:
        handles = [cl.submit(SearchRequest(**kw)) for kw in NN_SCHEDULE]
        done = {h.uid: h.result() for h in handles}
    finally:
        cl.close()
    if cache:
        # the leg must actually exercise the cache (re-expansions hit)
        assert reg.get("sim_cache_hits_total").value > 0
    _NN_RESULTS[key] = done
    return done


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
def test_nn_backend_cache_is_semantics_free(executor):
    """Acceptance: the transposition cache never changes results — the
    NN-backed schedule with CachedSimBackend equals the cache-off run
    bit for bit on every executor (relaxed/wavefront included: whatever
    an executor computes, caching must not perturb it)."""
    _assert_requests_identical(_run_nn(executor, True),
                               _run_nn(executor, False),
                               f"nn-cache/{executor}")


@pytest.mark.parametrize("executor", [e for e in EXECUTOR_NAMES
                                      if e in BIT_COMPATIBLE])
def test_nn_backend_matches_reference(executor):
    """Acceptance: NN-backed runs are bit-identical across the
    bit-compatible executors for a fixed request stream — the serving
    stack (microbatch padding + fixed-shape forward) keeps per-row
    inference results executor-agnostic."""
    _assert_requests_identical(_run_nn(executor, False),
                               _run_nn("reference", False),
                               f"nn-vs-reference/{executor}")
