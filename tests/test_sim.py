"""Tests for the simulation serving subsystem (repro.sim).

Pins the invariants the serving stack's bit-identity guarantees rest on:

  * NNSimBackend's vectorized evaluate matches a per-row reference with
    the same masked-softmax semantics, bit for bit, and each row's
    result is independent of batch composition;
  * SimServer returns the same per-row results regardless of how rows
    were split across submits / padded / coalesced, packs microbatches
    in priority order, and genuinely defers finalize to collect();
  * SimCache hits are bit-identical to the cold evaluate that populated
    them, the LRU bound holds, and hit/miss/evict counters land in the
    registry;
  * LMContinuationBackend is deterministic and pool-size invariant.
"""

import numpy as np
import pytest

from repro.envs import GomokuEnv
from repro.obs.metrics import MetricsRegistry
from repro.sim import (CachedSimBackend, PRIORITY_CLASSES, SimCache,
                       SimServer)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


# ---------------------------------------------------------------- helpers

def _gomoku_states(n, seed=0, max_plies=36):
    """n mid-game Gomoku states from random playouts (terminal rows kept:
    the backend's terminal-override path must be exercised too)."""
    env = GomokuEnv()
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        s = env.initial_state(0)
        for _ in range(int(rng.integers(0, max_plies + 1))):
            k = env.num_actions(s)
            if k == 0:
                break
            s, _, _ = env.step(s, int(rng.integers(k)))
        out.append(s)
    return np.stack(out)


@pytest.fixture(scope="module")
def nn_backend():
    import jax

    from repro.envs.policy_net import NNSimBackend, init_params

    env = GomokuEnv()
    return NNSimBackend(env, init_params(jax.random.PRNGKey(0), channels=8))


class _RecordingBackend:
    """evaluate-only fake: records every batch it sees; value = row sum
    (a pure per-row function, so padding/composition cannot leak)."""

    def __init__(self, n_actions=0):
        self.batches = []
        self.n_actions = n_actions

    def evaluate(self, states):
        states = np.asarray(states)
        self.batches.append(states.copy())
        vals = states.sum(axis=1).astype(np.float32)
        if not self.n_actions:
            return vals, None
        pri = np.tile(vals[:, None], (1, self.n_actions)).astype(np.float32)
        return vals, pri


class _SplitBackend(_RecordingBackend):
    """dispatch/finalize fake: counts phase transitions so tests can pin
    that SimServer dispatches on submit and finalizes only at collect."""

    def __init__(self, n_actions=0):
        super().__init__(n_actions)
        self.dispatched = 0
        self.finalized = 0

    def dispatch(self, states):
        self.dispatched += 1
        return np.asarray(states).copy()

    def finalize(self, token, states):
        self.finalized += 1
        return super().evaluate(states)

    def evaluate(self, states):  # pragma: no cover - split is preferred
        raise AssertionError("server should use the dispatch/finalize split")


# ------------------------------------------------- NNSimBackend semantics

def test_vectorized_evaluate_matches_rowwise_reference(nn_backend):
    """The one-pass numpy evaluate == a per-row reference with identical
    masked-softmax semantics (fixed-width 36-cell reductions)."""
    import jax

    from repro.envs.policy_net import _infer

    states = _gomoku_states(48, seed=1)
    vals, pris = nn_backend.evaluate(states)

    values, logits = jax.device_get(
        _infer(nn_backend.params,
               np.asarray([st[3:39].reshape(6, 6) * st[0] for st in states],
                          np.float32)))
    for i, st in enumerate(states):
        term = st[1] != 0
        if term:
            w, me = st[2], st[0]
            exp_v = np.float32(0.0 if w == 0 else (1.0 if w == me else -1.0))
            exp_p = np.zeros(36, np.float32)
        else:
            exp_v = np.float32(values[i])
            legal = st[3:39] == 0
            z = np.where(legal, logits[i], np.float32(-np.inf))
            ez = np.exp(z - z.max())
            soft = ez / ez.sum()
            exp_p = np.zeros(36, np.float32)
            exp_p[: legal.sum()] = soft[legal]
        assert vals[i] == exp_v, i
        np.testing.assert_array_equal(pris[i], exp_p, err_msg=str(i))


def test_evaluate_row_independent_of_batch_composition(nn_backend):
    states = _gomoku_states(16, seed=2)
    vals, pris = nn_backend.evaluate(states)
    perm = np.random.default_rng(0).permutation(len(states))
    pvals, ppris = nn_backend.evaluate(states[perm])
    np.testing.assert_array_equal(pvals, vals[perm])
    np.testing.assert_array_equal(ppris, pris[perm])


# ------------------------------------------------------------- SimServer

def test_server_split_submits_match_one_shot(nn_backend):
    states = _gomoku_states(24, seed=3)
    ref_v, ref_p = nn_backend.evaluate(states)

    srv = SimServer(nn_backend, max_batch=8)
    t1 = srv.submit(states[:5])
    t2 = srv.submit(states[5:16])
    t3 = srv.submit(states[16:])
    for t, sl in ((t1, slice(0, 5)), (t2, slice(5, 16)), (t3, slice(16, 24))):
        v, p = srv.collect(t)
        np.testing.assert_array_equal(v, ref_v[sl])
        np.testing.assert_array_equal(p, ref_p[sl])


def test_server_pads_partial_batches_to_fixed_shape():
    be = _RecordingBackend()
    srv = SimServer(be, max_batch=8)
    states = np.arange(3 * 4, dtype=np.float32).reshape(3, 4)
    v, p = srv.collect(srv.submit(states))
    assert p is None
    np.testing.assert_array_equal(v, states.sum(axis=1))
    (batch,) = be.batches
    assert batch.shape == (8, 4)                      # padded to max_batch
    np.testing.assert_array_equal(batch[3:], np.tile(states[0], (5, 1)))


def test_server_priority_order_within_microbatch():
    be = _RecordingBackend()
    srv = SimServer(be, max_batch=16)
    rows = {c: np.full((2, 3), i, np.float32)
            for i, c in enumerate(PRIORITY_CLASSES)}
    # submit in REVERSE priority order; the flush must reorder
    tickets = {c: srv.submit(rows[c], priority=c)
               for c in reversed(PRIORITY_CLASSES)}
    srv.collect(tickets["interactive"])
    (batch,) = be.batches
    np.testing.assert_array_equal(
        batch[:6], np.concatenate([rows[c] for c in PRIORITY_CLASSES]))
    for c in PRIORITY_CLASSES:                         # all rows landed
        v, _ = srv.collect(tickets[c])
        np.testing.assert_array_equal(v, rows[c].sum(axis=1))


def test_server_dispatches_on_submit_finalizes_on_collect():
    be = _SplitBackend()
    srv = SimServer(be, max_batch=4)
    t = srv.submit(np.ones((9, 2), np.float32))       # 2 full batches + 1
    assert (be.dispatched, be.finalized) == (2, 0)
    srv.collect(t)                                    # partial flush + finalize
    assert (be.dispatched, be.finalized) == (3, 3)


def test_server_rejects_unknown_priority_and_double_collect():
    srv = SimServer(_RecordingBackend(), max_batch=4)
    with pytest.raises(ValueError, match="priority"):
        srv.submit(np.zeros((1, 2), np.float32), priority="bulk")
    with pytest.raises(ValueError, match="priority"):
        SimServer(_RecordingBackend(), default_priority="bulk")
    t = srv.submit(np.zeros((2, 2), np.float32))
    srv.collect(t)
    t.filled = 0                                      # forged ticket
    with pytest.raises(RuntimeError, match="collect"):
        srv.collect(t)


def test_server_metrics():
    reg = MetricsRegistry()
    srv = SimServer(_RecordingBackend(), max_batch=4, metrics=reg)
    srv.collect(srv.submit(np.zeros((6, 2), np.float32)))
    assert reg.get("sim_server_batches_total").value == 2
    assert reg.get("sim_server_rows_total", priority="batch").value == 6
    assert reg.get("sim_server_partial_flushes_total").value == 1
    assert reg.get("sim_server_queue_depth").value == 0


# -------------------------------------------------------------- SimCache

def test_cache_lru_bound_and_eviction_counter():
    reg = MetricsRegistry()
    cache = SimCache(capacity=4, metrics=reg)
    keys = [SimCache.key(np.full(3, i, np.float32)) for i in range(6)]
    for i, k in enumerate(keys):
        cache.put(k, float(i), None)
    assert len(cache) == 4
    assert reg.get("sim_cache_evictions_total").value == 2
    assert cache.get(keys[0]) is None and cache.get(keys[1]) is None
    assert cache.get(keys[2]) == (np.float32(2.0), None)
    cache.put(SimCache.key(np.full(3, 9, np.float32)), 9.0, None)
    # key 2 was just touched -> key 3 is now the LRU victim
    assert cache.get(keys[3]) is None
    assert cache.get(keys[2]) is not None
    assert reg.get("sim_cache_entries").value == 4


def test_cached_backend_warm_hits_bit_identical(nn_backend):
    states = _gomoku_states(16, seed=4)
    ref_v, ref_p = nn_backend.evaluate(states)

    reg = MetricsRegistry()
    cached = CachedSimBackend(SimServer(nn_backend, max_batch=8),
                              capacity=64, metrics=reg)
    cold_v, cold_p = cached.evaluate(states)
    warm_v, warm_p = cached.evaluate(states)
    for v, p in ((cold_v, cold_p), (warm_v, warm_p)):
        np.testing.assert_array_equal(v, ref_v)
        np.testing.assert_array_equal(p, ref_p)
    assert reg.get("sim_cache_misses_total").value == 16
    assert reg.get("sim_cache_hits_total").value == 16


def test_cached_backend_mixed_hit_miss_batch():
    be = _RecordingBackend(n_actions=2)
    cached = CachedSimBackend(be, capacity=64)
    a = np.arange(8, dtype=np.float32).reshape(4, 2)
    cached.evaluate(a)
    b = np.arange(4, 12, dtype=np.float32).reshape(4, 2)  # rows 0,1 cached
    v, p = cached.evaluate(b)
    np.testing.assert_array_equal(v, b.sum(axis=1))
    np.testing.assert_array_equal(p, np.tile(v[:, None], (1, 2)))
    assert len(be.batches) == 2
    assert be.batches[1].shape == (2, 2)              # only the misses went in


# -------------------------------------------- LM continuation determinism

def test_lm_backend_deterministic_and_pool_invariant():
    import jax

    from repro import configs
    from repro.models import lm
    from repro.sim import LMContinuationBackend, LMTreeEnv

    cfg = configs.get_config("llama3.2-1b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    env = LMTreeEnv(cfg, params, fanout=4, horizon=2)
    states = np.stack([env.initial_state(s) for s in range(5)])

    ref, _ = LMContinuationBackend(env, pool_size=4).evaluate(states)
    again, _ = LMContinuationBackend(env, pool_size=4).evaluate(states)
    np.testing.assert_array_equal(again, ref)
    # NOTE: pool_size is NOT composition-free — the LM forward's batch
    # shape changes its reductions, which can flip a greedy argmax and
    # take a different continuation.  The serving guarantee is fixed-
    # config determinism (pinned above), not pool-size invariance.
    reuse = LMContinuationBackend(env, pool_size=4)
    first, _ = reuse.evaluate(states)
    second, _ = reuse.evaluate(states)           # batcher state fully drains
    np.testing.assert_array_equal(first, ref)
    np.testing.assert_array_equal(second, ref)
