"""Optimizers, gradient compression, sharding rules, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.optim import (
    adafactor, adamw, clip_by_global_norm, cosine_schedule, make_optimizer,
)
from repro.optim.compression import (
    compress_int8, decompress_int8, int8_roundtrip, topk_sparsify,
)


# ------------------------------------------------------------- optimizers

def test_adamw_decreases_quadratic():
    init, update = make_optimizer("adamw", lr=0.1, warmup=1, total=200,
                                  weight_decay=0.0)
    p = {"x": jnp.asarray([3.0, -2.0])}
    st_ = init(p)
    for i in range(150):
        g = {"x": 2 * p["x"]}
        u, st_ = update(g, st_, p, i)
        p = jax.tree.map(lambda a, b: a + b, p, u)
    assert float(jnp.abs(p["x"]).max()) < 0.15


def test_adafactor_decreases_and_factored_state():
    init, update = make_optimizer("adafactor", lr=0.05, warmup=1, total=300)
    p = {"w": jnp.ones((256, 256)) * 2.0}
    st_ = init(p)
    assert "vr" in st_["stats"]["w"]
    assert st_["stats"]["w"]["vr"].shape == (256,)
    for i in range(80):
        g = {"w": 2 * p["w"]}
        u, st_ = update(g, st_, p, i)
        p = jax.tree.map(lambda a, b: a + b, p, u)
    assert float(jnp.abs(p["w"]).mean()) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(gn), 20.0)
    norm = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert np.isclose(norm, 1.0, atol=1e-5)


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110)
    assert float(lr(0)) == 0.0
    assert np.isclose(float(lr(10)), 1.0, atol=1e-6)
    assert float(lr(110)) < 1e-6


# ------------------------------------------------------------ compression

@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_error(seed):
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(128).astype(np.float32))
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    # max error <= scale/2
    assert float(jnp.abs(back - g).max()) <= float(s) / 2 + 1e-7


def test_topk_sparsify_error_feedback():
    g = jnp.asarray(np.arange(100, dtype=np.float32))
    sparse, resid = topk_sparsify(g, frac=0.1)
    assert int((sparse != 0).sum()) == 10
    np.testing.assert_allclose(np.asarray(sparse + resid), np.asarray(g))


def test_int8_transform_preserves_training():
    grads = {"w": jnp.asarray(np.random.RandomState(0).randn(64, 64),
                              jnp.float32)}
    out = int8_roundtrip(grads)
    rel = float(jnp.linalg.norm(out["w"] - grads["w"])
                / jnp.linalg.norm(grads["w"]))
    assert rel < 0.01


# --------------------------------------------------------------- sharding

def test_param_spec_fallbacks():
    from repro.models.sharding import Rules, spec_for_param
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # use a fake 16-way model mesh via explicit sizes by monkeypatching the
    # divisibility path: simulate with a mesh dict-like
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    def norm(e):
        # PartitionSpec normalizes 1-tuples to bare names
        return e if isinstance(e, tuple) else ((e,) if e else None)

    r = Rules()
    # deepseek experts: 256 % 16 == 0 -> experts dim sharded
    spec = spec_for_param(FakeMesh, r, ("experts", "embed", "mlp"),
                          (256, 7168, 2048))
    assert norm(spec[0]) == ("model",)
    # mixtral: 8 experts don't divide -> falls through to mlp dim
    spec = spec_for_param(FakeMesh, r, ("experts", "embed", "mlp"),
                          (8, 6144, 16384))
    assert spec[0] is None and norm(spec[2]) == ("model",)
    # paligemma 8 heads -> head dim unsharded
    spec = spec_for_param(FakeMesh, r, ("embed", "heads", "head_dim"),
                          (2048, 8, 256))
    assert spec[1] is None
    # fsdp shards the largest remaining dim over data
    r2 = Rules(fsdp_params=True, fsdp_min_size=0)
    spec = spec_for_param(FakeMesh, r2, ("embed", "mlp"), (4096, 12800))
    assert norm(spec[0]) == ("data",) and norm(spec[1]) == ("model",)


def test_constrain_noop_without_mesh():
    from repro.models.sharding import constrain, set_context
    set_context(None)
    x = jnp.ones((4, 4))
    assert constrain(x, ("batch", "embed")) is x


# -------------------------------------------------------------- data

def test_data_determinism():
    from repro.data import SyntheticTokens
    a = SyntheticTokens(512, 4, 32, seed=5).batch_at(17)
    b = SyntheticTokens(512, 4, 32, seed=5).batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(512, 4, 32, seed=6).batch_at(17)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetcher_order():
    from repro.data import Prefetcher, SyntheticTokens
    src = SyntheticTokens(64, 2, 8, seed=0)
    pf = Prefetcher(src, start_step=3, depth=2)
    try:
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        assert (s0, s1) == (3, 4)
        np.testing.assert_array_equal(b0["tokens"], src.batch_at(3)["tokens"])
    finally:
        pf.close()
