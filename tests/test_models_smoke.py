"""Per-arch smoke tests: reduced same-family configs run one forward /
train step / prefill / decode on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm, steps
from repro.models.config import param_count
from repro.optim import make_optimizer

B, S = 2, 24


def make_batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.vlm_patches:
        batch["patches"] = jnp.full(
            (B, cfg.vlm_patches, cfg.d_model), 0.01, jnp.float32)
    if cfg.encoder is not None:
        batch["frames"] = jnp.full(
            (B, cfg.encoder.n_frames, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    batch = make_batch(cfg, key)

    logits, _, aux = lm.forward(cfg, params, batch["tokens"],
                                patches=batch.get("patches"),
                                frames=batch.get("frames"), impl="naive")
    exp_len = S + (cfg.vlm_patches or 0)
    assert logits.shape == (B, exp_len, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    init, update = make_optimizer("adamw", lr=1e-3)
    ts = jax.jit(steps.make_train_step(cfg, update, impl="naive"))
    # step 1: cosine warmup gives lr=0 at step 0 by construction
    params2, _, m = ts(params, init(params), 1, batch)
    assert np.isfinite(float(m["loss"]))
    # params actually changed
    changed = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(np.any(np.asarray(a) != np.asarray(b))),
                     params, params2))
    assert changed


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode(arch):
    cfg = configs.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key)
    batch = make_batch(cfg, key)
    caches = lm.init_caches(cfg, B, max_seq=S + 8)
    pre = jax.jit(steps.make_prefill_step(cfg, impl="naive"))
    dec = jax.jit(steps.make_decode_step(cfg, impl="naive"))
    kw = {k: batch[k] for k in ("patches", "frames") if k in batch}
    lg, caches = pre(params, batch["tokens"], caches, **kw)
    assert lg.shape == (B, cfg.padded_vocab)
    tok = jnp.argmax(lg, -1)[:, None]
    for i in range(2):
        lg, caches = dec(params, caches, tok, jnp.asarray(S + i))
        tok = jnp.argmax(lg, -1)[:, None]
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))


def test_full_config_param_counts():
    """Full configs match published sizes (sanity of the exact numbers)."""
    expect = {
        "llama3.2-1b": 1.24e9, "granite-3-8b": 8.2e9, "starcoder2-3b": 3.0e9,
        "gemma3-12b": 11.8e9, "paligemma-3b": 2.5e9,
        "recurrentgemma-9b": 8.5e9, "mamba2-2.7b": 2.7e9,
        "whisper-small": 0.23e9, "deepseek-v3-671b": 681.7e9,
        "mixtral-8x22b": 140.4e9,
    }
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        n = param_count(cfg)
        e = expect[cfg.name]
        assert abs(n - e) / e < 0.05, (cfg.name, n, e)


def test_decode_matches_prefill_logits():
    """Stepwise decode must reproduce teacher-forced forward logits
    (KV-cache correctness, llama smoke)."""
    cfg = configs.get_config("llama3.2-1b", smoke=True)
    key = jax.random.PRNGKey(2)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab)
    full_logits, _, _ = lm.forward(cfg, params, toks, impl="naive")

    caches = lm.init_caches(cfg, 1, max_seq=16)
    pre = steps.make_prefill_step(cfg, impl="naive")
    dec = steps.make_decode_step(cfg, impl="naive")
    lg, caches = pre(params, toks[:, :8], caches)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, 7]),
                               atol=1e-4, rtol=1e-4)
    for i in range(8, 12):
        lg, caches = dec(params, caches, toks[:, i : i + 1], jnp.asarray(i))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, i]),
                                   atol=1e-4, rtol=1e-4, err_msg=f"pos {i}")


def test_mla_absorbed_decode_equivalence():
    """§Perf optimization correctness: absorbed-MLA decode == naive MLA
    decode (same math, reordered matmuls)."""
    import dataclasses
    cfg = configs.get_config("deepseek-v3-671b", smoke=True)
    key = jax.random.PRNGKey(4)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 9), 0, cfg.vocab)

    def decode_logits(c):
        caches = lm.init_caches(c, 2, max_seq=12)
        pre = steps.make_prefill_step(c, impl="naive")
        dec = steps.make_decode_step(c, impl="naive")
        lg, caches = pre(params, toks[:, :6], caches)
        outs = [lg]
        for i in range(6, 9):
            lg, caches = dec(params, caches, toks[:, i : i + 1],
                             jnp.asarray(i))
            outs.append(lg)
        return np.asarray(jnp.stack(outs))

    naive = decode_logits(dataclasses.replace(cfg, mla_absorb=False))
    absorbed = decode_logits(dataclasses.replace(cfg, mla_absorb=True))
    np.testing.assert_allclose(absorbed, naive, atol=2e-3, rtol=2e-3)


def test_decode_matches_prefill_ssm():
    """Same for the recurrent family (mamba2): chunked scan vs recurrence."""
    cfg = configs.get_config("mamba2-2.7b", smoke=True)
    key = jax.random.PRNGKey(3)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 10), 0, cfg.vocab)
    full_logits, _, _ = lm.forward(cfg, params, toks, impl="naive")
    caches = lm.init_caches(cfg, 1, max_seq=16)
    pre = steps.make_prefill_step(cfg, impl="naive")
    dec = steps.make_decode_step(cfg, impl="naive")
    lg, caches = pre(params, toks[:, :6], caches)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, 5]),
                               atol=2e-3, rtol=2e-3)
    for i in range(6, 10):
        lg, caches = dec(params, caches, toks[:, i : i + 1], jnp.asarray(i))
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, i]),
                                   atol=2e-3, rtol=2e-3, err_msg=f"pos {i}")
