"""Multi-arena frontend: config bucketing, routing bit-identity, and
persistent compaction sessions.

Three claim groups:

  * bucketing — core.tree.bucket_key groups configs iff nothing that can
    change a slot's bit evolution differs (fanout padding to Fp is the
    one semantics-free merge), and ServiceFrontend routes each request to
    the pool of its bucket;
  * routing bit-identity (acceptance) — a heterogeneous request mix
    through the frontend produces, per request, results bit-identical to
    a dedicated single-config SearchService run of that request, for
    EVERY executor in EXECUTOR_NAMES;
  * sessions (acceptance) — with persistent compaction and a stable
    active set the sub-arena is gathered once and re-gathered only on
    membership changes (admission / eviction / reroot), snapshot reads
    force the deferred scatter, per-superstep and persistent modes are
    bit-identical, and the hysteresis thresholds stop decision thrash.
"""

import numpy as np
import pytest

from repro.core import TreeConfig
from repro.core.executor import EXECUTOR_NAMES
from repro.core.tree import bucket_key, canonical_config
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import (
    ArenaPool, SearchRequest, SearchService, ServiceFrontend,
)

ENV = BanditTreeEnv(fanout=3, terminal_depth=10)
P = 4

CFG_A = TreeConfig(X=128, F=4, D=6)
CFG_B = TreeConfig(X=96, F=3, D=5)      # different shape class
CFG_C = TreeConfig(X=128, F=3, D=6)     # same bucket as CFG_A (Fp=4)

MIX = [CFG_A, CFG_B, CFG_C, CFG_A, CFG_B]


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_bucket_key_pads_fanout_only():
    assert bucket_key(CFG_A) == bucket_key(CFG_C)          # F=4 vs F=3, Fp=4
    assert bucket_key(CFG_A) != bucket_key(CFG_B)          # X and D differ
    base = TreeConfig(X=128, F=4, D=6)
    for other in (
        TreeConfig(X=64, F=4, D=6),                        # X is semantic
        TreeConfig(X=128, F=4, D=5),                       # D is semantic
        TreeConfig(X=128, F=8, D=6),                       # Fp differs
        TreeConfig(X=128, F=4, D=6, beta=2.0),
        TreeConfig(X=128, F=4, D=6, vl_mode="constant"),
        TreeConfig(X=128, F=4, D=6, score_fn="puct"),
        TreeConfig(X=128, F=4, D=6, leaf_mode="unexpanded",
                   expand_all=True),
    ):
        assert bucket_key(base) != bucket_key(other), other


def test_canonical_config_is_bucket_representative():
    canon = canonical_config(CFG_C)
    assert canon.F == CFG_C.Fp == 4
    assert bucket_key(canon) == bucket_key(CFG_C)
    assert canonical_config(canon) == canon


def test_frontend_routes_by_bucket():
    fe = ServiceFrontend(ENV, BanditValueBackend(), G=2, p=P)
    pools = [fe.submit(SearchRequest(uid=i, seed=i, budget=2, cfg=cfg))
             for i, cfg in enumerate(MIX)]
    assert len(fe.pools) == 2
    assert pools[0] is pools[2] is pools[3]                # CFG_A bucket
    assert pools[1] is pools[4]                            # CFG_B bucket
    assert pools[0] is not pools[1]
    fe.run()
    fe.close()


def test_frontend_requires_some_config():
    fe = ServiceFrontend(ENV, BanditValueBackend(), G=2, p=P)
    with pytest.raises(ValueError, match="no TreeConfig"):
        fe.submit(SearchRequest(uid=0, seed=0))
    fe.close()


def test_default_cfg_serves_bare_requests():
    fe = ServiceFrontend(ENV, BanditValueBackend(), G=2, p=P,
                         default_cfg=CFG_A)
    fe.submit(SearchRequest(uid=0, seed=0, budget=2))
    (res,) = fe.run()
    assert res.uid == 0 and res.actions
    fe.close()


def test_pool_rejects_foreign_config():
    pool = ArenaPool(CFG_A, ENV, BanditValueBackend(), G=2, p=P)
    with pytest.raises(ValueError, match="bucket"):
        pool.submit(SearchRequest(uid=0, seed=0, cfg=CFG_B))
    pool.close()


# ---------------------------------------------------------------------------
# routing bit-identity (acceptance)
# ---------------------------------------------------------------------------

def _mix_requests():
    return [SearchRequest(uid=i, seed=10 + i, budget=3, moves=1 + i % 2,
                          keep_tree=True, cfg=cfg)
            for i, cfg in enumerate(MIX)]


def _assert_result_equal(got, want, label):
    assert got.actions == want.actions, label
    assert got.rewards == want.rewards, label
    assert got.supersteps == want.supersteps, label
    for va, vb in zip(got.visit_counts, want.visit_counts):
        np.testing.assert_array_equal(va, vb, err_msg=label)
    for k in want.tree_snapshot:
        np.testing.assert_array_equal(
            got.tree_snapshot[k], want.tree_snapshot[k],
            err_msg=f"{label} field={k}")


@pytest.mark.parametrize("executor", EXECUTOR_NAMES)
def test_heterogeneous_mix_matches_dedicated_services(executor):
    """Acceptance: every request of a mixed-config batch through the
    frontend is bit-identical to the same request on a dedicated
    single-config SearchService of its own (unpadded) config."""
    fe = ServiceFrontend(ENV, BanditValueBackend(), G=2, p=P,
                         executor=executor, compact_threshold=0.6,
                         persistent_compaction=True)
    try:
        for req in _mix_requests():
            fe.submit(req)
        done = {r.uid: r for r in fe.run()}
    finally:
        fe.close()
    assert sorted(done) == list(range(len(MIX)))

    for req in _mix_requests():
        svc = SearchService(req.cfg, ENV, BanditValueBackend(), G=1, p=P,
                            executor=executor)
        try:
            svc.submit(SearchRequest(uid=req.uid, seed=req.seed,
                                     budget=req.budget, moves=req.moves,
                                     keep_tree=True))
            (ref,) = svc.run()
        finally:
            svc.close()
        _assert_result_equal(done[req.uid], ref,
                             f"{executor} uid={req.uid}")


# ---------------------------------------------------------------------------
# persistent compaction sessions
# ---------------------------------------------------------------------------

def _low_occupancy_service(executor="faithful", persistent=True, **kw):
    # G=4 with a single active slot: always below the enter threshold,
    # so every superstep runs on the (gathered or resident) sub-arena
    return SearchService(CFG_A, ENV, BanditValueBackend(), G=4, p=P,
                         executor=executor, compact_threshold=0.5,
                         persistent_compaction=persistent, **kw)


@pytest.mark.parametrize("executor", ["reference", "faithful"])
def test_stable_set_gathers_once(executor):
    """Acceptance: a stable active set pays ONE gather for the whole run;
    the scatter is deferred to the eviction-time snapshot read."""
    budget = 6
    svc = _low_occupancy_service(executor)
    svc.submit(SearchRequest(uid=0, seed=1, budget=budget))
    svc.run()
    svc.close()
    s = svc.stats
    assert s.compacted_supersteps == budget
    assert s.session_gathers == 1
    assert s.session_reuses == budget - 1
    assert s.session_scatters == 1          # the final snapshot sync


def test_per_superstep_mode_regathers_every_superstep():
    """persistent_compaction=False restores the old cost model: one
    gather + one scatter per compacted superstep."""
    budget = 5
    svc = _low_occupancy_service(persistent=False)
    svc.submit(SearchRequest(uid=0, seed=1, budget=budget))
    svc.run()
    svc.close()
    s = svc.stats
    assert s.compacted_supersteps == budget
    assert s.session_gathers == budget
    assert s.session_reuses == 0


def test_admission_invalidates_session():
    """Admitting into a fresh slot changes the membership set, so exactly
    one extra gather happens — not one per superstep."""
    svc = _low_occupancy_service()
    svc.submit(SearchRequest(uid=0, seed=1, budget=7))
    for _ in range(3):
        svc.superstep()
    assert svc.stats.session_gathers == 1
    svc.submit(SearchRequest(uid=1, seed=2, budget=4))
    svc.run()
    svc.close()
    assert svc.stats.session_gathers == 2   # re-gather at the admission
    # ... plus the eviction of uid=1 (before uid=0 drains) re-gathers once
    # more at most; membership changes, never supersteps, drive gathers
    assert svc.stats.session_gathers + svc.stats.session_reuses \
        == svc.stats.compacted_supersteps


def test_reroot_invalidates_session_and_snapshot_forces_scatter():
    """A multi-move request reroots its slot in place at each move
    boundary: the membership set is unchanged but the slot's content is
    rewritten on the full arena, so the session must end (and the
    boundary's snapshot read must have scattered first)."""
    budget, moves = 4, 3
    svc = _low_occupancy_service()
    svc.submit(SearchRequest(uid=0, seed=3, budget=budget, moves=moves,
                             keep_tree=True))
    (res,) = svc.run()
    svc.close()
    s = svc.stats
    assert len(res.actions) == moves
    assert s.compacted_supersteps == budget * moves
    assert s.session_gathers == moves       # one per move segment
    assert s.session_reuses == (budget - 1) * moves
    assert s.session_scatters == moves      # each move's snapshot sync
    # the snapshot the result carries must include the last superstep's
    # work (the deferred scatter really happened before the read)
    snap = res.tree_snapshot
    assert np.all(snap["edge_VL"] == 0) and np.all(snap["node_O"] == 0)
    assert int(snap["size"]) > 1


@pytest.mark.parametrize("executor", ["reference", "faithful", "pallas"])
def test_persistent_sessions_bit_identical_to_per_superstep(executor):
    """Sessions are a pure cost optimization: deferring the scatter can
    never change what any slot computes."""
    def go(persistent):
        svc = SearchService(CFG_A, ENV, BanditValueBackend(), G=4, p=P,
                            executor=executor, compact_threshold=0.6,
                            persistent_compaction=persistent)
        try:
            for i in range(3):
                svc.submit(SearchRequest(uid=i, seed=30 + i,
                                         budget=3 + i, moves=1 + i % 2,
                                         keep_tree=True))
            return {r.uid: r for r in svc.run()}, svc.stats
        finally:
            svc.close()

    per, s_per = go(False)
    ses, s_ses = go(True)
    assert s_per.supersteps == s_ses.supersteps
    assert s_ses.session_gathers < s_per.session_gathers
    assert s_ses.session_reuses > 0
    for uid in per:
        _assert_result_equal(ses[uid], per[uid], f"uid={uid}")


def test_hysteresis_thresholds_stop_decision_thrash():
    """Occupancy oscillating between the enter and exit thresholds keeps
    the compacted decision stable; with exit == enter (default) the same
    oscillation flips the decision every tick."""
    def decisions(enter, exit_, As):
        svc = SearchService(CFG_A, ENV, BanditValueBackend(), G=8, p=P,
                            compact_threshold=enter,
                            compact_exit_threshold=exit_)
        out = []
        for a in As:
            active = np.zeros(8, bool)
            active[:a] = True
            svc._pick_execution(active)
            out.append(svc.last_decision["compacted"])
        svc.close()
        return out

    osc = [2, 3, 2, 3, 2]
    assert decisions(0.25, 0.5, osc) == [True] * 5          # hysteresis holds
    assert decisions(0.25, None, osc) == [True, False] * 2 + [True]
    # rising past the exit threshold really does exit, and the pool does
    # not re-enter until occupancy falls back below the enter threshold
    assert decisions(0.25, 0.5, [2, 4, 5, 4, 2]) == \
        [True, True, False, False, True]


def test_hysteresis_exit_below_enter_rejected():
    with pytest.raises(AssertionError, match="hysteresis"):
        SearchService(CFG_A, ENV, BanditValueBackend(), G=4, p=P,
                      compact_threshold=0.5, compact_exit_threshold=0.25)
