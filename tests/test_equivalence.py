"""The paper's central correctness claim: the accelerated system produces
the EXACT same outputs as the sequential CPU program — tested bit-for-bit
between the numpy oracle (ref_sequential) and the batched jit executor,
across tree configs, VL variants, scoring functions and expansion modes.
"""

import dataclasses

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import TreeConfig, TreeParallelMCTS, RolloutBackend
from repro.core import ref_sequential as ref
from repro.envs import BanditTreeEnv


def run_system(executor, cfg, p, supersteps, env_kw=None, seed=3):
    env = BanditTreeEnv(**(env_kw or dict(fanout=cfg.F, terminal_depth=cfg.D + 2,
                                          varying_fanout=True)))
    m = TreeParallelMCTS(cfg, env, RolloutBackend(env, max_steps=8, seed=7),
                         p=p, executor=executor, seed=seed)
    for _ in range(supersteps):
        m.superstep()
    snap = m.exec.snapshot(m.tree)
    return snap


CONFIGS = [
    TreeConfig(X=128, F=3, D=4, vl_mode="wu", score_fn="uct"),
    TreeConfig(X=128, F=5, D=4, vl_mode="constant", vl_const=0.3,
               score_fn="uct"),
    TreeConfig(X=256, F=4, D=6, vl_mode="wu", score_fn="puct",
               leaf_mode="unexpanded", expand_all=True),
    TreeConfig(X=64, F=8, D=3, vl_mode="constant", score_fn="puct",
               leaf_mode="unexpanded", expand_all=True),
]


@pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: f"{c.vl_mode}-{c.score_fn}")
@pytest.mark.parametrize("p", [1, 5, 16])
def test_jax_matches_sequential_oracle(cfg, p):
    a = run_system("reference", cfg, p, supersteps=5)
    b = run_system("faithful", cfg, p, supersteps=5)
    for k in a:
        if k == "log_table":
            continue
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


@given(seed=st.integers(0, 10_000), p=st.integers(1, 9),
       f=st.integers(2, 6), d=st.integers(2, 5))
@settings(max_examples=15, deadline=None)
def test_equivalence_property(seed, p, f, d):
    cfg = TreeConfig(X=96, F=f, D=d, vl_mode="wu")
    a = run_system("reference", cfg, p, supersteps=3, seed=seed)
    b = run_system("faithful", cfg, p, supersteps=3, seed=seed)
    for k in ("child", "edge_N", "edge_W", "edge_VL", "node_N", "node_O",
              "size", "num_expanded"):
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


@pytest.mark.parametrize("executor", ["reference", "faithful", "wavefront"])
def test_virtual_loss_recovery(executor):
    """After every superstep completes its backup, no virtual loss or
    in-flight counters may remain (paper: VL is recovered in BackUp)."""
    cfg = TreeConfig(X=128, F=4, D=5)
    snap = run_system(executor, cfg, p=8, supersteps=6)
    assert np.all(snap["edge_VL"] == 0)
    assert np.all(snap["node_O"] == 0)


def test_tree_invariants():
    """Structural invariants after several supersteps."""
    cfg = TreeConfig(X=256, F=4, D=6)
    snap = run_system("faithful", cfg, p=8, supersteps=8)
    size = int(snap["size"])
    child, edge_n = snap["child"], snap["edge_N"]
    node_n = snap["node_N"]
    expanded = child >= 0
    # every expanded child id is unique and within size
    ids = child[expanded]
    assert ids.size == np.unique(ids).size
    assert ids.max(initial=0) < size
    # node_N >= sum of child edge_N (each visit descends through one edge)
    assert np.all(node_n >= edge_n.sum(axis=1))
    # num_expanded matches child links
    assert np.array_equal(snap["num_expanded"], expanded.sum(axis=1))


def test_distinct_expansion_invariant():
    """Paper §III-B: all workers expand different nodes, so ST writes never
    collide (the StateTable asserts this internally — run a system with
    heavy leaf contention and rely on those asserts)."""
    cfg = TreeConfig(X=64, F=2, D=3)  # tiny: forces many same-leaf workers
    run_system("faithful", cfg, p=12, supersteps=6)


def test_relaxed_collapses_wavefront_diversifies():
    """The naive one-shot relaxation loses worker diversity; the rank-based
    wavefront restores most of it (beyond-paper §Perf evidence)."""
    cfg = TreeConfig(X=512, F=6, D=6)
    env = BanditTreeEnv(fanout=6, terminal_depth=10)

    def leaves(executor):
        m = TreeParallelMCTS(cfg, env, RolloutBackend(env, max_steps=4),
                             p=16, executor=executor)
        m.superstep()
        sel = m.superstep()
        return len(np.unique(sel["leaves"]))

    assert leaves("wavefront") > leaves("relaxed")
