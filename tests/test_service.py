"""Multi-tree search service: arena correctness + scheduler behaviour.

The load-bearing claims:
  1. the vmapped arena is a pure batching transform — every slot's tree
     evolves bit-identically to a single-tree run of the same request
     against the sequential numpy oracle;
  2. the scheduler actually schedules — more queued searches than slots
     complete, via admission into freed slots, with the Simulation phase
     fused across trees into one evaluate() batch.
"""

import numpy as np
import pytest

from repro.core import TreeConfig, TreeParallelMCTS
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import (
    JaxArenaExecutor, SearchRequest, SearchService,
)

CFG = TreeConfig(X=256, F=4, D=6)
ENV = BanditTreeEnv(fanout=4, terminal_depth=10)
P = 8


def _service(G, executor="faithful", **kw):
    return SearchService(CFG, ENV, BanditValueBackend(), G=G, p=P,
                         executor=executor, **kw)


def _single_tree_reference(seed, supersteps):
    m = TreeParallelMCTS(CFG, ENV, BanditValueBackend(), p=P,
                         executor="reference", seed=seed)
    for _ in range(supersteps):
        m.superstep()
    return m.exec.snapshot(m.tree), m.exec.best_action(m.tree)


def test_arena_bit_identical_to_single_tree_reference():
    """Acceptance (a): a G=4 arena run equals 4 independent single-tree
    runs of the sequential reference executor, bit for bit."""
    G, budget = 4, 6
    svc = _service(G)
    for i in range(G):
        svc.submit(SearchRequest(uid=i, seed=i, budget=budget, keep_tree=True))
    done = {r.uid: r for r in svc.run()}
    assert sorted(done) == list(range(G))
    for i in range(G):
        ref_snap, ref_action = _single_tree_reference(i, budget)
        snap = done[i].tree_snapshot
        for k in ref_snap:
            np.testing.assert_array_equal(ref_snap[k], snap[k],
                                          err_msg=f"uid={i} field={k}")
        assert done[i].actions == [ref_action]
        np.testing.assert_array_equal(
            done[i].visit_counts[0],
            ref_snap["edge_N"][int(ref_snap["root"])][: CFG.F])


def test_scheduler_oversubscription_and_fused_batching():
    """Acceptance (b): more queued searches than slots all complete
    (admission + eviction), and simulation batches span multiple trees."""
    G, n_req = 2, 5
    svc = _service(G)
    for i in range(n_req):
        svc.submit(SearchRequest(uid=i, seed=i, budget=4))
    done = svc.run()
    assert sorted(r.uid for r in done) == list(range(n_req))
    assert n_req > G
    # fused Simulation: while both slots were occupied, one evaluate()
    # call carried G * p rows (cross-tree batch), not p
    assert svc.stats.max_fused_rows == G * P
    assert svc.stats.sim_batches == svc.stats.supersteps
    # 5 searches x 4 supersteps over 2 slots => at least ceil(20/2) ticks
    assert svc.stats.supersteps >= 10


def test_reference_arena_matches_jit_arena():
    """The scheduler is executor-agnostic: the sequential per-slot oracle
    and the vmapped jit arena produce identical results and schedules."""
    def go(executor):
        svc = _service(2, executor=executor)
        for i in range(4):
            svc.submit(SearchRequest(uid=i, seed=10 + i, budget=5,
                                     keep_tree=True))
        return {r.uid: r for r in svc.run()}

    a, b = go("reference"), go("faithful")
    assert sorted(a) == sorted(b)
    for uid in a:
        assert a[uid].actions == b[uid].actions
        assert a[uid].supersteps == b[uid].supersteps
        for k in a[uid].tree_snapshot:
            np.testing.assert_array_equal(
                a[uid].tree_snapshot[k], b[uid].tree_snapshot[k],
                err_msg=f"uid={uid} field={k}")


def test_multi_move_request_advances_via_reroot():
    """A long-lived request plays several moves on one slot; the chosen
    subtree's statistics survive each move boundary and the quiescence
    invariants (VL == O == 0) hold at eviction."""
    svc = _service(2)
    svc.submit(SearchRequest(uid=0, seed=3, budget=5, moves=3,
                             keep_tree=True))
    (res,) = svc.run()
    assert len(res.actions) == len(res.rewards) == len(res.visit_counts) == 3
    snap = res.tree_snapshot
    assert np.all(snap["edge_VL"] == 0) and np.all(snap["node_O"] == 0)
    # subtree reuse means later moves start warm: the tree at eviction is
    # bigger than one move's insertions alone would leave after a flush
    assert int(snap["size"]) > 1
    assert res.supersteps == 15


def test_multi_move_flush_fallback_matches_fresh_searches():
    """With subtree reuse off, every move starts from a flushed tree — so move
    k of a multi-move request equals a fresh single-move search from the
    same state."""
    svc = _service(1, reuse_subtree=False)
    svc.submit(SearchRequest(uid=0, seed=7, budget=4, moves=2))
    (res,) = svc.run()

    # replay move 2 as its own request from the post-move-1 state
    s1, _, _ = ENV.step(ENV.initial_state(7), res.actions[0])

    class _Env(BanditTreeEnv):
        def initial_state(self, seed):
            return s1

    svc2 = SearchService(CFG, _Env(fanout=4, terminal_depth=10),
                         BanditValueBackend(), G=1, p=P, executor="faithful")
    svc2.submit(SearchRequest(uid=1, seed=0, budget=4))
    (res2,) = svc2.run()
    assert res.actions[1] == res2.actions[0]
    np.testing.assert_array_equal(res.visit_counts[1], res2.visit_counts[0])


def test_idle_slots_are_frozen():
    """An occupied slot's tree must be untouched by supersteps that only
    concern other slots: admit one request on a G=3 arena and check the
    other slots stay at their initial state."""
    svc = _service(3)
    svc.submit(SearchRequest(uid=0, seed=1, budget=3))
    svc.run()
    for g in (1, 2):
        snap = svc.exec.slot_snapshot(g)
        assert int(snap["size"]) == 1
        assert snap["node_N"].sum() == 0 and snap["edge_N"].sum() == 0


def test_staggered_admission_is_deterministic():
    """Requests admitted mid-flight (into a freed slot) see exactly the
    same search as when run alone: scheduling changes when a tree's
    supersteps happen, never what they compute."""
    svc = _service(2)
    for i in range(6):
        svc.submit(SearchRequest(uid=i, seed=20 + i, budget=3,
                                 keep_tree=True))
    done = {r.uid: r for r in svc.run()}
    # uid=5 was admitted after several evictions; compare to a solo run
    solo = _service(1)
    solo.submit(SearchRequest(uid=5, seed=25, budget=3, keep_tree=True))
    (ref,) = solo.run()
    assert done[5].actions == ref.actions
    for k in ref.tree_snapshot:
        np.testing.assert_array_equal(ref.tree_snapshot[k],
                                      done[5].tree_snapshot[k], err_msg=k)


def test_expand_all_puct_service_runs():
    """Gomoku-style config (expand-all + PUCT priors) through the fused
    service path: priors are split per slot and the trees stay quiescent."""
    import jax
    from repro.envs import GomokuEnv
    from repro.envs.policy_net import NNSimBackend, init_params

    env = GomokuEnv()
    cfg = TreeConfig(X=128, F=36, D=5, beta=5.0, score_fn="puct",
                     leaf_mode="unexpanded", expand_all=True)
    backend = NNSimBackend(env, init_params(jax.random.PRNGKey(0)))
    svc = SearchService(cfg, env, backend, G=2, p=4, executor="faithful",
                        alternating_signs=True)
    for i in range(2):
        svc.submit(SearchRequest(uid=i, seed=i, budget=3, keep_tree=True))
    done = svc.run()
    assert len(done) == 2
    for r in done:
        s = r.tree_snapshot
        assert int(s["size"]) > 1
        assert np.all(s["edge_VL"] == 0) and np.all(s["node_O"] == 0)
        assert s["edge_P"].any()  # priors landed


def test_pallas_is_first_class_arena_executor():
    """The arena-native kernels serve the arena directly: "pallas" is its
    own executor in the unified stack (not a JaxExecutor variant, which
    still rejects the name — the jit and kernel paths stay distinct)."""
    from repro.service import PallasArenaExecutor, make_arena_executor
    ex = make_arena_executor(CFG, 2, "pallas")
    assert isinstance(ex, PallasArenaExecutor)
    assert ex.G == 2
    with pytest.raises(NotImplementedError):
        JaxArenaExecutor(CFG, 2, variant="pallas")
