"""Fixed-point encoding properties (paper §IV-C claims)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import fixedpoint as fx

finite_f32 = st.floats(min_value=-1000.0, max_value=1000.0,
                       allow_nan=False, width=32)


@given(finite_f32, finite_f32)
@settings(max_examples=200, deadline=None)
def test_encode_monotone(a, b):
    ea, eb = int(fx.encode(np.float32(a))), int(fx.encode(np.float32(b)))
    if a < b:
        assert ea <= eb
    elif a > b:
        assert ea >= eb


@given(finite_f32)
@settings(max_examples=200, deadline=None)
def test_roundtrip_error_bound(x):
    # Qm.16: quantization error <= 2^-17 relative to the encoded value
    d = float(fx.decode(fx.encode(np.float32(x))))
    assert abs(d - np.float32(x)) <= 2.0 ** -16


@given(st.floats(allow_nan=False, width=32), st.floats(allow_nan=False, width=32))
@settings(max_examples=300, deadline=None)
def test_ordered_i32_bijection(a, b):
    a, b = np.float32(a), np.float32(b)
    ia, ib = fx.f32_to_ordered_i32(a), fx.f32_to_ordered_i32(b)
    assert (a < b) == (ia < ib) or a == b
    assert fx.ordered_i32_to_f32(ia) == a


def test_paper_precision_claim():
    """Paper §IV-C: the fixed-point loss on the exploration term "is within
    0.01%, insignificant compared to typical 1%-40% virtual loss applied
    to the uct value" — i.e. the quantization error is <0.01% OF THE UCT
    VALUE (Q + U), far below the VL perturbations that drive selection."""
    rng = np.random.RandomState(0)
    X = 56_000
    for _ in range(200):
        n_parent = rng.randint(1, X)
        n_child = rng.randint(1, n_parent + 1, size=6).astype(np.float32)
        q = rng.uniform(0.2, 1.0, size=6).astype(np.float32)  # V_hat
        explore = np.sqrt(np.log(n_parent).astype(np.float32) / n_child)
        uct = q + explore
        err = np.abs(fx.decode(fx.encode(uct)) - uct)
        assert np.all(err <= 2.0 ** -16)            # absolute Qm.16 bound
        assert np.all(err / uct < 1e-4)             # < 0.01% of uct value
        # and orders of magnitude below the smallest (1%) virtual loss
        assert np.all(err < 0.01 * uct * 0.1)


def test_bitwidth_sizing_rule():
    ub = fx.uct_upper_bound(v_max=1.0, beta=1.0, x_nodes=56_000)
    bits = fx.integer_bits_for(ub)
    assert 2 <= bits <= 16
    assert int(fx.encode(np.float32(ub))) < fx.FX_FORCE_EXPLORE
