"""Quickstart: Tree-Parallel MCTS with the accelerated in-tree operations.

Builds the paper's system (Fig. 2) on a deterministic toy environment:
p parallel workers, UCT statistics on the accelerator (batched jit ops —
swap executor="pallas" for the Pallas kernels), environment states in the
host State Table, BSP supersteps, one full MCTS step with Tree Flush.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import TreeConfig, TreeParallelMCTS, RolloutBackend
from repro.envs import BanditTreeEnv


def main():
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfg = TreeConfig(
        X=1024,          # node budget per MCTS step (tree-flush boundary)
        F=6,             # fanout = action-space size
        D=9,             # tree height limit
        vl_mode="wu",    # WU-UCT visit-count virtual loss (paper default)
    )
    sim = RolloutBackend(env, max_steps=32, seed=0)

    mcts = TreeParallelMCTS(cfg, env, sim, p=16, executor="faithful")
    total = 0.0
    for step in range(5):
        action, reward, terminal = mcts.run_step(max_supersteps=30)
        total += reward
        s = mcts.stats
        print(f"step {step}: action={action} reward={reward:+.3f} "
              f"supersteps={s.supersteps} "
              f"intree={s.t_intree:.3f}s sim={s.t_sim:.3f}s")
        if terminal:
            break
    print(f"total reward: {total:+.3f}")


if __name__ == "__main__":
    main()
