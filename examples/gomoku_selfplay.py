"""Gomoku self-play with DNN simulation (paper benchmark b, end to end).

Replicates the paper's Gomoku setup: 6x6 board, expand-all, PUCT with a
policy-value network as the Simulation phase — then closes the loop by
training the network on the self-play targets (AlphaZero-style), i.e. the
paper's system embedded in its intended application.

  PYTHONPATH=src python examples/gomoku_selfplay.py --games 2 --p 8
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TreeConfig, TreeParallelMCTS
from repro.envs import GomokuEnv
from repro.envs.policy_net import NNSimBackend, apply, init_params

CFG = TreeConfig(X=384, F=36, D=5, beta=5.0, score_fn="puct",
                 leaf_mode="unexpanded", expand_all=True)


def play_game(env, params, p, seed, max_moves=36, supersteps=8):
    backend = NNSimBackend(env, params)
    s = env.initial_state(seed)
    states, players = [], []
    mcts = TreeParallelMCTS(CFG, env, backend, p=p, executor="faithful",
                            alternating_signs=True, seed=seed)
    for _ in range(max_moves):
        mcts.root_state = s
        mcts.st.flush(s)
        mcts.tree = mcts.exec.init(env.num_actions(s))
        for _ in range(supersteps):
            mcts.superstep()
        a = mcts.exec.best_action(mcts.tree)
        states.append(s.copy())
        players.append(s[0])
        s, r, term = env.step(s, a)
        if term:
            break
    winner = s[2]
    # value targets from each mover's perspective
    z = [0.0 if winner == 0 else (1.0 if pl == winner else -1.0)
         for pl in players]
    return states, z, winner


def train_net(params, states, z, lr=1e-2, epochs=30):
    boards = np.stack([st[3:39].reshape(6, 6) * st[0] for st in states])
    targets = jnp.asarray(z, jnp.float32)

    def loss_fn(p):
        v, _ = apply(p, jnp.asarray(boards, jnp.float32))
        return jnp.mean((v - targets) ** 2)

    g = jax.jit(jax.value_and_grad(loss_fn))
    for _ in range(epochs):
        l, grads = g(params)
        params = jax.tree.map(lambda a, b: a - lr * b, params, grads)
    return params, float(l)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--games", type=int, default=2)
    ap.add_argument("--p", type=int, default=8)
    args = ap.parse_args()

    env = GomokuEnv()
    params = init_params(jax.random.PRNGKey(0))
    buf_s, buf_z = [], []
    for g in range(args.games):
        states, z, winner = play_game(env, params, args.p, seed=g)
        buf_s += states
        buf_z += z
        params, loss = train_net(params, buf_s, buf_z)
        print(f"game {g}: {len(states)} moves, winner={winner:+.0f}, "
              f"value-loss={loss:.4f}")
    print("self-play loop complete")


if __name__ == "__main__":
    main()
