"""Gomoku self-play with DNN simulation (paper benchmark b, end to end).

Replicates the paper's Gomoku setup: 6x6 board, expand-all, PUCT with a
policy-value network as the Simulation phase — then closes the loop by
training the network on the self-play targets (AlphaZero-style).

Served through the full client stack: every game is one multi-move
SearchRequest on a SearchClient, the G game slots run concurrently in
one arena, and the network runs behind the sim-serving subsystem
(repro.sim) — a SimServer microbatches all slots' inference rows into
fixed-shape batches (the paper Fig. 5 batching) at priority class
"self-play", with a transposition cache in front so re-expanded
positions skip inference entirely.

  PYTHONPATH=src python examples/gomoku_selfplay.py --games 2 --p 8
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TreeConfig
from repro.envs import GomokuEnv
from repro.envs.policy_net import NNSimBackend, apply, init_params
from repro.service import SearchClient, SearchRequest
from repro.sim import CachedSimBackend, SimServer

CFG = TreeConfig(X=384, F=36, D=5, beta=5.0, score_fn="puct",
                 leaf_mode="unexpanded", expand_all=True)


def play_games(env, params, n_games, p, G=4, budget=8, max_batch=64,
               cache_capacity=4096, uid_base=0):
    """Self-play n_games concurrently through one SearchClient; returns
    (states, value targets, winners) replayed from the committed moves."""
    sim = CachedSimBackend(
        SimServer(NNSimBackend(env, params), max_batch=max_batch,
                  default_priority="self-play"),
        capacity=cache_capacity)
    client = SearchClient(env, sim_backend=sim, G=G, p=p,
                          executor="faithful", default_cfg=CFG,
                          alternating_signs=True)
    try:
        handles = [client.submit(
            SearchRequest(uid=uid_base + g, seed=g, budget=budget,
                          moves=env.max_actions))
            for g in range(n_games)]
        results = [h.result() for h in handles]
    finally:
        client.close()
    buf_s, buf_z, winners = [], [], []
    for g, res in enumerate(results):
        s = env.initial_state(g)
        states, players = [], []
        for a in res.actions:
            states.append(s.copy())
            players.append(s[0])
            s, _, term = env.step(s, a)
            if term:
                break
        winner = s[2]
        buf_s += states
        buf_z += [0.0 if winner == 0 else (1.0 if pl == winner else -1.0)
                  for pl in players]
        winners.append(winner)
    return buf_s, buf_z, winners


def train_net(params, states, z, lr=1e-2, epochs=30):
    boards = np.stack([st[3:39].reshape(6, 6) * st[0] for st in states])
    targets = jnp.asarray(z, jnp.float32)

    def loss_fn(p):
        v, _ = apply(p, jnp.asarray(boards, jnp.float32))
        return jnp.mean((v - targets) ** 2)

    g = jax.jit(jax.value_and_grad(loss_fn))
    for _ in range(epochs):
        l, grads = g(params)
        params = jax.tree.map(lambda a, b: a - lr * b, params, grads)
    return params, float(l)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--games", type=int, default=2)
    ap.add_argument("--p", type=int, default=8)
    ap.add_argument("--G", type=int, default=4,
                    help="concurrent game slots per self-play round")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="SimServer microbatch size")
    args = ap.parse_args()

    env = GomokuEnv()
    params = init_params(jax.random.PRNGKey(0))
    buf_s, buf_z = [], []
    for rnd in range(args.games):
        states, z, winners = play_games(
            env, params, n_games=1, p=args.p, G=args.G,
            max_batch=args.max_batch, uid_base=rnd * args.G)
        buf_s += states
        buf_z += z
        params, loss = train_net(params, buf_s, buf_z)
        print(f"game {rnd}: {len(states)} moves, "
              f"winner={winners[0]:+.0f}, value-loss={loss:.4f}")
    print("self-play loop complete")


if __name__ == "__main__":
    main()
