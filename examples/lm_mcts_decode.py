"""Token-level MCTS decoding with an LM simulation backend.

The paper's Gomoku benchmark replaces rollouts with DNN inference; this
example pushes that to its modern conclusion: the simulation backend is a
language model's serve path, and MCTS plans over next-token actions —
the tree machinery (UCT on accelerator, ST on host) is untouched.

Environment: states are token sequences (stored in the ST); actions are
the top-F tokens proposed by the LM at each node; the simulation value is
the LM's average log-likelihood of a greedy continuation (a standard
search-decoding score).

  PYTHONPATH=src python examples/lm_mcts_decode.py --tokens 6
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import TreeConfig, TreeParallelMCTS
from repro.models import lm

MAXLEN = 48


class LMTreeEnv:
    """Token-sequence environment over a (smoke) LM."""

    state_dtype = np.float32

    def __init__(self, cfg, params, fanout=6, horizon=5):
        self.cfg, self.params, self.F, self.horizon = cfg, params, fanout, horizon
        self.state_shape = (MAXLEN + 1,)   # [len, tokens...]
        self.max_actions = fanout
        self._fwd = jax.jit(
            lambda p, t: lm.forward(cfg, p, t, impl="naive")[0])

    def initial_state(self, seed):
        s = np.zeros(MAXLEN + 1, np.float32)
        s[0] = 1
        s[1] = 1 + seed % 7
        return s

    def tokens(self, state):
        n = int(state[0])
        return np.asarray(state[1 : 1 + n], np.int64)

    def top_actions(self, state):
        t = jnp.asarray(self.tokens(state))[None]
        logits = np.asarray(self._fwd(self.params, t))[0, -1]
        return np.argsort(-logits)[: self.F]

    def num_actions(self, state):
        return 0 if int(state[0]) >= MAXLEN - self.horizon else self.F

    def step(self, state, a):
        tok = int(self.top_actions(state)[a])
        s = state.copy()
        n = int(s[0])
        s[1 + n] = tok
        s[0] = n + 1
        return s, 0.0, int(s[0]) >= MAXLEN - self.horizon


class LMSimBackend:
    """Simulation = greedy LM continuation scored by mean log-prob."""

    def __init__(self, env: LMTreeEnv):
        self.env = env

    def evaluate(self, states):
        vals = np.zeros(len(states), np.float32)
        for i, s in enumerate(states):
            toks = self.env.tokens(s)
            lp = 0.0
            t = jnp.asarray(toks)[None]
            for _ in range(self.env.horizon):
                logits = np.asarray(self.env._fwd(self.env.params, t))[0, -1]
                p = logits - np.logaddexp.reduce(logits)
                nxt = int(np.argmax(p))
                lp += p[nxt]
                t = jnp.concatenate([t, jnp.asarray([[nxt]])], axis=1)
            vals[i] = lp / self.env.horizon
        return vals, None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--tokens", type=int, default=6)
    ap.add_argument("--p", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    env = LMTreeEnv(cfg, params)
    tree_cfg = TreeConfig(X=96, F=env.F, D=4)
    mcts = TreeParallelMCTS(tree_cfg, env, LMSimBackend(env), p=args.p,
                            executor="faithful")

    seq = [int(env.initial_state(0)[1])]
    for t in range(args.tokens):
        a, _, term = mcts.run_step(max_supersteps=10)
        seq.append(int(mcts.root_state[int(mcts.root_state[0])]))
        print(f"token {t}: planned action {a}; sequence so far {seq}")
        if term:
            break
    print("decoded:", seq)


if __name__ == "__main__":
    main()
