"""Token-level MCTS decoding with an LM simulation backend, fully served.

The paper's Gomoku benchmark replaces rollouts with DNN inference; this
example pushes that to its modern conclusion: the simulation backend is a
language model's serve path, and MCTS plans over next-token actions —
the tree machinery (UCT on accelerator, ST on host) is untouched.

The workload runs through the production stack end to end: the decode is
one multi-move SearchRequest on a SearchClient at priority class
"interactive", tokens stream out of SearchHandle.moves() as each reroot
commits, and simulation batches flow through repro.sim — a SimServer
microbatches the tree's leaf rows, and LMContinuationBackend scores each
row's greedy continuation by mean token log-prob, decoding ALL rows
concurrently through one ContinuousBatcher pool (serving/batcher.py)
instead of the historical per-row forward loop.

  PYTHONPATH=src python examples/lm_mcts_decode.py --tokens 6
"""

import argparse

import jax

from repro import configs
from repro.core import TreeConfig
from repro.models import lm
from repro.service import SearchClient, SearchRequest
from repro.sim import LMContinuationBackend, LMTreeEnv, SimServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--tokens", type=int, default=6)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--pool-size", type=int, default=8,
                    help="ContinuousBatcher decode pool (LM microbatch)")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    env = LMTreeEnv(cfg, params)
    sim = SimServer(LMContinuationBackend(env, pool_size=args.pool_size),
                    max_batch=args.p, default_priority="interactive")
    tree_cfg = TreeConfig(X=96, F=env.F, D=4)

    with SearchClient(env, sim_backend=sim, G=1, p=args.p,
                      executor="faithful", default_cfg=tree_cfg) as client:
        handle = client.submit(SearchRequest(
            uid=0, seed=0, budget=8, moves=args.tokens))
        state = env.initial_state(0)
        seq = [int(state[1])]
        for ev in handle.moves():
            state, _, term = env.step(state, ev.action)
            seq.append(int(state[int(state[0])]))
            print(f"token {ev.move_index}: planned action {ev.action}; "
                  f"sequence so far {seq}")
            if term:
                break
    print("decoded:", seq)


if __name__ == "__main__":
    main()
