"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on the synthetic pipeline, with checkpointing and resume.

This wraps the production launcher (repro.launch.train) with a purpose-
built ~100M config — deliverable (b)'s "train ~100M model for a few
hundred steps" driver.  On this single-CPU container expect ~20+ minutes
for the full 200 steps; pass --steps 20 for a quick look.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

import jax

from repro.models import lm, steps as msteps
from repro.models.config import LayerSpec, ModelConfig, param_count
from repro.data import Prefetcher, SyntheticTokens
from repro.distributed import CheckpointManager
from repro.optim import make_optimizer
import jax.numpy as jnp

CFG_100M = ModelConfig(
    name="repro-100m",
    d_model=640, n_heads=10, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab=32000,
    groups=(((LayerSpec(),), 12),),
    tie_embeddings=True, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"[100m] params: {param_count(cfg):,}")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    init, update = make_optimizer("adamw", lr=3e-4, warmup=20,
                                  total=args.steps)
    opt = init(params)
    train = jax.jit(msteps.make_train_step(cfg, update, impl="blockwise"))

    mgr = CheckpointManager(args.ckpt, keep_last=2, async_save=True)
    start = 0
    s, state, _ = mgr.restore_latest({"params": params, "opt": opt})
    if s is not None:
        start, params, opt = s + 1, state["params"], state["opt"]
        print(f"[100m] resumed at {start}")

    src = SyntheticTokens(cfg.vocab, args.batch, args.seq, seed=0)
    pf = Prefetcher(src, start_step=start)
    try:
        for _ in range(start, args.steps):
            i, batch = pf.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, m = train(params, opt, jnp.asarray(i), batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"[100m] step {i:4d} loss {float(m['loss']):.4f}",
                      flush=True)
            if i and i % 50 == 0:
                mgr.save(i, {"params": params, "opt": opt})
        mgr.save(args.steps - 1, {"params": params, "opt": opt})
        mgr.wait()
    finally:
        pf.close()
    print("[100m] done")


if __name__ == "__main__":
    main()
