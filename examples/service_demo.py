"""Multi-tree search service demo: many users, one scheduler.

Default mode queues 12 search requests (mixed budgets, some multi-move)
over a 4-slot tree arena: each superstep advances every occupied slot
through one Selection / Insertion / Simulation / BackUp round in a
single device program per phase, with all slots' simulation states fused
into one backend batch.  Completed searches are evicted and the freed
slot is immediately refilled from the queue; once the queue drains,
occupancy decays and the scheduler gathers the active slots into a
dense, device-resident sub-arena (watch the per-superstep decision
trace).

--client switches to the SearchClient handle API — the serving surface
the paper's narrow CPU<->accelerator interface maps to.  Requests carry
THREE different TreeConfig shape classes and are routed into per-config
arena pools by the global scheduler under --policy:

  round-robin           one pool per tick, rotating (the compat default)
  weighted-queue-depth  every pool with work advances each tick, deepest
                        backlog first, admission caps proportional to
                        queue-depth share — and the tick's Simulation
                        rows from ALL pools fuse into ONE evaluate()
  deadline-aware        the pool holding the nearest deadline goes first

The client mode streams: each handle's moves() generator yields per-move
action/visit-distribution events as the reroots commit (iterating IS
serving — no drain-to-completion), one request carries a deadline it
cannot meet (watch it come back "evicted"), and one is cancelled
mid-flight.  Cold pools retire after --retire-after idle ticks (their
arena is freed; watch the pool summary) and resurrect on demand.

--overlap (client mode) turns on pipelined supersteps: each pool's
slots are split into --gangs gangs and the superstep is double-buffered
— gang A's host half (expansion + simulation IPC) runs while gang B's
device in-tree phases (select -> insert) are already dispatched through
JAX's async queue.  Results are bit-identical to lock-step; the summary
prints the host-wait / device-wait / overlapped pipeline split.

--frontend keeps the pre-handle ServiceFrontend adapter path.

Observability (client mode): --trace-out records every superstep phase
(select / expand / simulate / backup / compact-gather / compact-scatter)
and request lifecycle (submit -> admit -> move commits -> result /
cancel / evict) on per-pool timelines and writes Chrome-trace JSON;
--metrics prints the Prometheus text snapshot (queue depths, smoothed
load, admission waits, fused-batch sizes, evictions, expirations).

To view a trace: open https://ui.perfetto.dev in a browser, click
"Open trace file" and pick trace.json (chrome://tracing also works).
Tracks are one per arena pool plus the scheduler; zoom into any
"superstep" span to see the select/expand/simulate/backup phase split —
the Fig. 8-style breakdown the paper's CPU/FPGA numbers rest on.

  PYTHONPATH=src python examples/service_demo.py
  PYTHONPATH=src python examples/service_demo.py --executor pallas
  PYTHONPATH=src python examples/service_demo.py --frontend
  PYTHONPATH=src python examples/service_demo.py --client
  PYTHONPATH=src python examples/service_demo.py --client \
      --policy weighted-queue-depth --trace-out trace.json --metrics
  PYTHONPATH=src python examples/service_demo.py --client --overlap \
      --expansion pool --gangs 2
"""

import argparse
import time

import numpy as np

from repro.core import TreeConfig
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import (
    POLICY_NAMES, SearchClient, SearchRequest, SearchService,
    ServiceFrontend,
)

CFGS = (TreeConfig(X=512, F=6, D=8),    # deep, big arena
        TreeConfig(X=256, F=6, D=6),    # mid
        TreeConfig(X=128, F=6, D=4))    # shallow, latency-lean


def run_client(args):
    """SearchClient handle API: opaque handles, streamed moves, policies,
    deadlines, cancellation and cold-pool retirement."""
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    # overlap mode double-buffers gangs, which is incompatible with
    # compaction (slot rows must stay put while a gang is in flight)
    compact = 0.0 if args.overlap else 0.5
    client = SearchClient(
        env, BanditValueBackend(), G=4, p=16,
        executor=args.executor, expansion=args.expansion,
        policy=args.policy, retire_after_ticks=args.retire_after,
        compact_threshold=compact,
        compact_exit_threshold=0.75 if compact else None,
        supersteps_per_dispatch=args.supersteps_per_dispatch,
        n_shards=args.shards,
        overlap=args.overlap, n_gangs=args.gangs,
        trace=bool(args.trace_out), metrics=args.metrics,
    )
    t_serve0 = time.perf_counter()
    handles = [client.submit(SearchRequest(
        uid=i, seed=i, budget=6 + 2 * (i % 4), moves=1 if i % 3 else 3,
        cfg=CFGS[i % len(CFGS)]), priority=i % 2)
        for i in range(10)]
    # one request that cannot make its deadline, one we cancel mid-flight
    doomed = client.submit(
        SearchRequest(uid=98, seed=98, budget=40, cfg=CFGS[0]),
        deadline_supersteps=8)
    victim = client.submit(
        SearchRequest(uid=99, seed=99, budget=6, moves=4, cfg=CFGS[1]))

    # stream one long-lived request move by move: iterating moves() polls
    # the scheduler, so every other handle advances underneath it
    streamer = next(h for h in handles if not h.uid % 3)
    print(f"streaming handle uid={streamer.uid} "
          f"({args.policy} policy, everyone else advances underneath):")
    for ev in streamer.moves():
        print(f"  move {ev.move_index}: action={ev.action} "
              f"reward={ev.reward:+.3f} last={ev.last} "
              f"visits={np.asarray(ev.visit_counts).tolist()}")
        if ev.move_index == 1 and not victim.done():
            victim.cancel()
            print(f"  (cancelled uid={victim.uid} mid-flight: "
                  f"status={victim.status()})")

    client.run_until(lambda c: all(h.done() for h in handles)
                     and doomed.done())
    t_serve = time.perf_counter() - t_serve0
    for h in sorted(handles + [doomed, victim], key=lambda h: h.uid):
        r = h.result(wait=False)
        print(f"req {h.uid:2d}: status={h.status():9s} "
              f"actions={r.actions} supersteps={r.supersteps}")

    # drive a few idle ticks against a late request so cold pools retire
    late = client.submit(SearchRequest(uid=100, seed=7, budget=30,
                                       cfg=CFGS[0]))
    late.result()
    print("\npools (cold ones retire after "
          f"{args.retire_after} idle ticks):")
    for ps in client.pool_summaries():
        state = "RETIRED" if ps["retired"] else f"load={ps['active']}"
        print(f"  bucket X={ps['cfg'].X} D={ps['cfg'].D}: "
              f"{ps['completed']} done in {ps['supersteps']} supersteps "
              f"[{state}, idle={ps['idle_ticks']}]")
    s = client.stats
    if args.overlap:
        # per-pool pipeline split: host wait (expansion/sim IPC) vs
        # device wait (staged in-tree readback) vs overlapped wall time
        wall = host = dev = 0.0
        for pool in client.core.pools.values():
            wall += pool._ov_wall
            host += pool._ov_wait_host
            dev += pool._ov_wait_dev
        hid = max(wall - host - dev, 0.0)
        print(f"\noverlap pipeline ({args.gangs} gangs): "
              f"{t_serve:.3f}s serving wall; per-tick split "
              f"host-wait {host:.3f}s / device-wait {dev:.3f}s / "
              f"overlapped {hid:.3f}s "
              f"({100.0 * hid / max(wall, 1e-9):.0f}% of pipeline time "
              f"hidden behind the other gang)")
    else:
        print(f"\nserving wall time {t_serve:.3f}s "
              f"(re-run with --overlap to double-buffer gangs)")
    print(f"{s.completed} results ({s.cancelled} cancelled, "
          f"{s.deadline_evictions} deadline-evicted, "
          f"{s.retirements} pool retirements) in {s.ticks} ticks; "
          f"p95 admission wait {s.wait_percentile(95)} ticks; "
          f"cross-pool fused batches: {client.core.xpool_batches} "
          f"(max {client.core.xpool_rows_max} rows vs best single-pool "
          f"{client.core.xpool_pool_rows_max})")
    if args.metrics:
        print("\nPrometheus snapshot:\n" + client.metrics())
    if args.trace_out:
        trace = client.trace_export(args.trace_out)
        print(f"\nwrote {len(trace['traceEvents'])} trace events to "
              f"{args.trace_out} ({client.tracer.dropped} dropped) — open "
              f"it at https://ui.perfetto.dev (Open trace file) or "
              f"chrome://tracing")
    client.close()


def run_frontend(args):
    """Heterogeneous-config serving through the pre-handle adapter."""
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    fe = ServiceFrontend(
        env, BanditValueBackend(), G=4, p=16,
        executor=args.executor, expansion=args.expansion,
        policy=args.policy,
        compact_threshold=0.5, compact_exit_threshold=0.75,
        supersteps_per_dispatch=args.supersteps_per_dispatch,
    )
    for i in range(12):
        fe.submit(SearchRequest(
            uid=i, seed=i, budget=6 + 2 * (i % 4), moves=1 if i % 3 else 2,
            cfg=CFGS[i % len(CFGS)],        # mixed shape classes
        ))
    while fe.superstep():
        pool = fe.pools[fe.last_key]
        d = pool.last_decision
        mode = (f"session[{d['session']}] sub-arena G={d['G_exec']}"
                if d["compacted"] else "masked full arena")
        print(f"tick {fe.stats.ticks:3d}: "
              f"bucket X={pool.cfg.X} D={pool.cfg.D} "
              f"{pool.load()}/{pool.G} slots active — {mode}")
    for r in sorted(fe.completed, key=lambda r: r.uid):
        print(f"req {r.uid:2d}: actions={r.actions} "
              f"reward={sum(r.rewards):+.3f} supersteps={r.supersteps}")
    print()
    for ps in fe.pool_summaries():
        print(f"bucket {ps['bucket'][:3]}: {ps['completed']} done in "
              f"{ps['supersteps']} supersteps; sessions: "
              f"{ps['session_gathers']} gathers / "
              f"{ps['session_reuses']} resident reuses / "
              f"{ps['session_scatters']} scatters")
    s = fe.stats
    print(f"\n{s.completed} searches over {len(fe.pools)} config buckets "
          f"in {s.supersteps} supersteps on executor={args.executor}")
    fe.close()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--executor", choices=("faithful", "pallas"),
                    default="faithful",
                    help="in-tree executor: vmapped jit arena (faithful) "
                         "or the arena-native [G]-grid Pallas kernels")
    ap.add_argument("--expansion", choices=("loop", "vector", "pool"),
                    default="vector",
                    help="host-expansion engine: per-worker env.step loop, "
                         "one flattened step_batch across all slots "
                         "(vector), or a process pool of scalar workers")
    ap.add_argument("--policy", choices=POLICY_NAMES, default="round-robin",
                    help="global schedule policy (client/frontend modes): "
                         "which pools advance each tick and how buckets "
                         "admit; weighted-queue-depth gang ticks fuse ONE "
                         "evaluate() batch across every pool")
    ap.add_argument("--supersteps-per-dispatch", type=int, default=1,
                    metavar="K",
                    help="fused K-superstep device dispatch: run up to K "
                         "supersteps per compiled program, escaping only "
                         "at move commits or host-bound expansions.  K>1 "
                         "needs device-evaluable env + sim twins (the "
                         "bandit env here has them; host-only backends "
                         "silently keep the K=1 phase-by-phase path)")
    ap.add_argument("--overlap", action="store_true",
                    help="client mode: pipelined supersteps — split each "
                         "pool's slots into --gangs gangs and double-"
                         "buffer the superstep, so one gang's host "
                         "expansion/simulation runs while the next gang's "
                         "device in-tree phases are already dispatched "
                         "(results stay bit-identical; disables "
                         "compaction, which needs slot rows to stay put)")
    ap.add_argument("--gangs", type=int, default=2, metavar="N",
                    help="client mode: gangs per pool for --overlap "
                         "(2 = classic double buffering)")
    ap.add_argument("--shards", type=int, default=1, metavar="D",
                    help="client mode: partition each bucket's G slots "
                         "across D per-device shard arenas (least-loaded "
                         "placement; results bit-identical to D=1).  Use "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=D for real per-shard devices on a CPU host")
    ap.add_argument("--retire-after", type=int, default=12, metavar="TICKS",
                    help="client mode: idle ticks before a cold pool "
                         "releases its arena (resurrected on demand)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="client mode: record phase + request-lifecycle "
                         "spans and write Chrome-trace JSON here (open at "
                         "ui.perfetto.dev)")
    ap.add_argument("--metrics", action="store_true",
                    help="client mode: print the Prometheus exposition "
                         "snapshot of the scheduler/pool telemetry")
    ap.add_argument("--client", action="store_true",
                    help="serve through the SearchClient handle API: "
                         "streamed moves(), priorities, deadlines, "
                         "cancellation, cold-pool retirement")
    ap.add_argument("--frontend", action="store_true",
                    help="serve a heterogeneous-config mix through the "
                         "pre-handle ServiceFrontend adapter")
    args = ap.parse_args()
    if args.client:
        return run_client(args)
    if args.frontend:
        return run_frontend(args)

    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfg = TreeConfig(X=512, F=6, D=8)
    svc = SearchService(
        cfg, env, BanditValueBackend(),
        G=4,                     # concurrent tree slots
        p=16,                    # workers (simulations) per tree per superstep
        executor=args.executor,  # unified stack ("reference" = numpy oracle)
        compact_threshold=0.5,   # opt-in: gather active slots when <= half
        expansion=args.expansion,  # batched host expansion (core.expand)
        supersteps_per_dispatch=args.supersteps_per_dispatch,
    )                            # the arena is occupied (see pool docs)

    for i in range(12):
        svc.submit(SearchRequest(
            uid=i,
            seed=i,
            budget=6 + 2 * (i % 4),        # mixed budgets: slots drain
            moves=1 if i % 3 else 2,       # unevenly, so the tail of the
        ))                                 # run exercises compaction


    # drive dispatch-by-dispatch to trace the occupancy/compaction choice
    # (a fused dispatch runs up to K supersteps per compiled program)
    K = args.supersteps_per_dispatch
    while (svc.fused_dispatch() if K > 1 else svc.superstep()):
        d = svc.last_decision
        mode = (f"session[{d['session']}] sub-arena G={d['G_exec']}"
                if d["compacted"] else "masked full arena")
        print(f"superstep {svc.stats.supersteps:3d}: "
              f"{svc.load()}/{d['G']} slots active "
              f"(occupancy {d['occupancy']:.2f}) — {mode}")

    done = svc.completed
    for r in sorted(done, key=lambda r: r.uid):
        dist = r.visit_counts[-1]
        print(f"req {r.uid:2d}: actions={r.actions} "
              f"reward={sum(r.rewards):+.3f} supersteps={r.supersteps} "
              f"last visit dist={np.asarray(dist).tolist()}")
    s = svc.stats
    print(f"\n{s.completed} searches in {s.supersteps} supersteps "
          f"on executor={args.executor} "
          f"({s.compacted_supersteps} compacted, "
          f"avg occupancy {s.occupancy_sum / max(s.supersteps, 1):.2f}); "
          f"fused sim batches: {s.sim_batches} "
          f"(max {s.max_fused_rows} states/batch); "
          f"intree={s.t_intree:.3f}s host={s.t_host:.3f}s sim={s.t_sim:.3f}s")


if __name__ == "__main__":
    main()
