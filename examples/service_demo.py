"""Multi-tree search service demo: many users, one arena.

Queues 12 search requests (mixed budgets, some multi-move) over a 4-slot
tree arena: each superstep advances every occupied slot through one
Selection / Insertion / Simulation / BackUp round in a single device
program per phase, with all slots' simulation states fused into one
backend batch.  Completed searches are evicted and the freed slot is
immediately refilled from the queue.

  PYTHONPATH=src python examples/service_demo.py
"""

import numpy as np

from repro.core import TreeConfig
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import SearchRequest, SearchService


def main():
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfg = TreeConfig(X=512, F=6, D=8)
    svc = SearchService(
        cfg, env, BanditValueBackend(),
        G=4,                   # concurrent tree slots
        p=16,                  # workers (simulations) per tree per superstep
        executor="faithful",   # vmapped jit arena ("reference" = numpy oracle)
    )

    for i in range(12):
        svc.submit(SearchRequest(
            uid=i,
            seed=i,
            budget=10,                     # supersteps per move
            moves=1 if i % 3 else 2,       # every third request plays 2 moves
        ))

    done = svc.run()
    for r in sorted(done, key=lambda r: r.uid):
        dist = r.visit_counts[-1]
        print(f"req {r.uid:2d}: actions={r.actions} "
              f"reward={sum(r.rewards):+.3f} supersteps={r.supersteps} "
              f"last visit dist={np.asarray(dist).tolist()}")
    s = svc.stats
    print(f"\n{s.completed} searches in {s.supersteps} supersteps; "
          f"fused sim batches: {s.sim_batches} "
          f"(max {s.max_fused_rows} states/batch); "
          f"intree={s.t_intree:.3f}s host={s.t_host:.3f}s sim={s.t_sim:.3f}s")


if __name__ == "__main__":
    main()
