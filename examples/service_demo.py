"""Multi-tree search service demo: many users, one arena.

Queues 12 search requests (mixed budgets, some multi-move) over a 4-slot
tree arena: each superstep advances every occupied slot through one
Selection / Insertion / Simulation / BackUp round in a single device
program per phase, with all slots' simulation states fused into one
backend batch.  Completed searches are evicted and the freed slot is
immediately refilled from the queue; once the queue drains, occupancy
decays and the scheduler switches from masked execution to gathering the
active slots into a dense sub-arena (watch the per-superstep decision
trace).

Host expansion runs through the batched engine (core.expand): with
--expansion vector (the default here) every occupied slot's pending
expansions are flattened into ONE env.step_batch call per superstep
instead of a per-slot, per-worker Python loop; --expansion pool serves
the same batch from a process pool of scalar-env workers (for envs with
no vectorized form), and --expansion loop is the original reference
path.  All three are bit-identical (tests/test_executor_matrix.py).

  PYTHONPATH=src python examples/service_demo.py
  PYTHONPATH=src python examples/service_demo.py --executor pallas
  PYTHONPATH=src python examples/service_demo.py --expansion loop
"""

import argparse

import numpy as np

from repro.core import TreeConfig
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import SearchRequest, SearchService


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--executor", choices=("faithful", "pallas"),
                    default="faithful",
                    help="in-tree executor: vmapped jit arena (faithful) "
                         "or the arena-native [G]-grid Pallas kernels")
    ap.add_argument("--expansion", choices=("loop", "vector", "pool"),
                    default="vector",
                    help="host-expansion engine: per-worker env.step loop, "
                         "one flattened step_batch across all slots "
                         "(vector), or a process pool of scalar workers")
    args = ap.parse_args()

    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfg = TreeConfig(X=512, F=6, D=8)
    svc = SearchService(
        cfg, env, BanditValueBackend(),
        G=4,                     # concurrent tree slots
        p=16,                    # workers (simulations) per tree per superstep
        executor=args.executor,  # unified stack ("reference" = numpy oracle)
        compact_threshold=0.5,   # opt-in: gather active slots when <= half
        expansion=args.expansion,  # batched host expansion (core.expand)
    )                            # the arena is occupied (see scheduler docs)

    for i in range(12):
        svc.submit(SearchRequest(
            uid=i,
            seed=i,
            budget=6 + 2 * (i % 4),        # mixed budgets: slots drain
            moves=1 if i % 3 else 2,       # unevenly, so the tail of the
        ))                                 # run exercises compaction

    # drive superstep-by-superstep to trace the occupancy/compaction choice
    while svc.superstep():
        d = svc.last_decision
        mode = (f"compacted -> sub-arena G={d['G_exec']}" if d["compacted"]
                else "masked full arena")
        print(f"superstep {svc.stats.supersteps:3d}: "
              f"{d['A']}/{d['G']} slots active "
              f"(occupancy {d['occupancy']:.2f}) — {mode}")

    done = svc.completed
    for r in sorted(done, key=lambda r: r.uid):
        dist = r.visit_counts[-1]
        print(f"req {r.uid:2d}: actions={r.actions} "
              f"reward={sum(r.rewards):+.3f} supersteps={r.supersteps} "
              f"last visit dist={np.asarray(dist).tolist()}")
    s = svc.stats
    print(f"\n{s.completed} searches in {s.supersteps} supersteps "
          f"on executor={args.executor} "
          f"({s.compacted_supersteps} compacted, "
          f"avg occupancy {s.occupancy_sum / max(s.supersteps, 1):.2f}); "
          f"fused sim batches: {s.sim_batches} "
          f"(max {s.max_fused_rows} states/batch); "
          f"intree={s.t_intree:.3f}s host={s.t_host:.3f}s sim={s.t_sim:.3f}s")


if __name__ == "__main__":
    main()
