"""Multi-tree search service demo: many users, one arena.

Queues 12 search requests (mixed budgets, some multi-move) over a 4-slot
tree arena: each superstep advances every occupied slot through one
Selection / Insertion / Simulation / BackUp round in a single device
program per phase, with all slots' simulation states fused into one
backend batch.  Completed searches are evicted and the freed slot is
immediately refilled from the queue; once the queue drains, occupancy
decays and the scheduler switches from masked execution to gathering the
active slots into a dense sub-arena (watch the per-superstep decision
trace).

Host expansion runs through the batched engine (core.expand): with
--expansion vector (the default here) every occupied slot's pending
expansions are flattened into ONE env.step_batch call per superstep
instead of a per-slot, per-worker Python loop; --expansion pool serves
the same batch from a process pool of scalar-env workers (for envs with
no vectorized form), and --expansion loop is the original reference
path.  All three are bit-identical (tests/test_executor_matrix.py).

Compaction is session-based: once occupancy drops below the threshold
the active slots are gathered ONCE into a device-resident sub-arena that
persists across supersteps (watch for "resident" in the trace) and is
scattered back only at membership changes or snapshot reads.

--frontend switches to the multi-arena ServiceFrontend: the same queue
but with requests carrying THREE different TreeConfig shape classes,
bucketed into per-config arena pools and round-robinned — the
heterogeneous-config serving mode a single SearchService cannot offer.

  PYTHONPATH=src python examples/service_demo.py
  PYTHONPATH=src python examples/service_demo.py --executor pallas
  PYTHONPATH=src python examples/service_demo.py --expansion loop
  PYTHONPATH=src python examples/service_demo.py --frontend
"""

import argparse

import numpy as np

from repro.core import TreeConfig
from repro.envs import BanditTreeEnv, BanditValueBackend
from repro.service import SearchRequest, SearchService, ServiceFrontend


def run_frontend(args):
    """Heterogeneous-config serving: one frontend, three config buckets."""
    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfgs = (TreeConfig(X=512, F=6, D=8),    # deep, big arena
            TreeConfig(X=256, F=6, D=6),    # mid
            TreeConfig(X=128, F=6, D=4))    # shallow, latency-lean
    fe = ServiceFrontend(
        env, BanditValueBackend(), G=4, p=16,
        executor=args.executor, expansion=args.expansion,
        compact_threshold=0.5, compact_exit_threshold=0.75,
    )
    for i in range(12):
        fe.submit(SearchRequest(
            uid=i, seed=i, budget=6 + 2 * (i % 4), moves=1 if i % 3 else 2,
            cfg=cfgs[i % len(cfgs)],        # mixed shape classes
        ))
    while fe.superstep():
        pool = fe.pools[fe.last_key]
        d = pool.last_decision
        mode = (f"session[{d['session']}] sub-arena G={d['G_exec']}"
                if d["compacted"] else "masked full arena")
        print(f"superstep {fe.stats.supersteps:3d}: "
              f"bucket X={pool.cfg.X} D={pool.cfg.D} "
              f"{d['A']}/{d['G']} slots active — {mode}")
    for r in sorted(fe.completed, key=lambda r: r.uid):
        print(f"req {r.uid:2d}: actions={r.actions} "
              f"reward={sum(r.rewards):+.3f} supersteps={r.supersteps}")
    print()
    for ps in fe.pool_summaries():
        print(f"bucket {ps['bucket'][:3]}: {ps['completed']} done in "
              f"{ps['supersteps']} supersteps; sessions: "
              f"{ps['session_gathers']} gathers / "
              f"{ps['session_reuses']} resident reuses / "
              f"{ps['session_scatters']} scatters")
    s = fe.stats
    print(f"\n{s.completed} searches over {len(fe.pools)} config buckets "
          f"in {s.supersteps} supersteps on executor={args.executor}")
    fe.close()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--executor", choices=("faithful", "pallas"),
                    default="faithful",
                    help="in-tree executor: vmapped jit arena (faithful) "
                         "or the arena-native [G]-grid Pallas kernels")
    ap.add_argument("--expansion", choices=("loop", "vector", "pool"),
                    default="vector",
                    help="host-expansion engine: per-worker env.step loop, "
                         "one flattened step_batch across all slots "
                         "(vector), or a process pool of scalar workers")
    ap.add_argument("--frontend", action="store_true",
                    help="serve a heterogeneous-config mix through the "
                         "multi-arena ServiceFrontend instead of one "
                         "single-config SearchService")
    args = ap.parse_args()
    if args.frontend:
        return run_frontend(args)

    env = BanditTreeEnv(fanout=6, terminal_depth=12)
    cfg = TreeConfig(X=512, F=6, D=8)
    svc = SearchService(
        cfg, env, BanditValueBackend(),
        G=4,                     # concurrent tree slots
        p=16,                    # workers (simulations) per tree per superstep
        executor=args.executor,  # unified stack ("reference" = numpy oracle)
        compact_threshold=0.5,   # opt-in: gather active slots when <= half
        expansion=args.expansion,  # batched host expansion (core.expand)
    )                            # the arena is occupied (see scheduler docs)

    for i in range(12):
        svc.submit(SearchRequest(
            uid=i,
            seed=i,
            budget=6 + 2 * (i % 4),        # mixed budgets: slots drain
            moves=1 if i % 3 else 2,       # unevenly, so the tail of the
        ))                                 # run exercises compaction

    # drive superstep-by-superstep to trace the occupancy/compaction choice
    while svc.superstep():
        d = svc.last_decision
        mode = (f"session[{d['session']}] sub-arena G={d['G_exec']}"
                if d["compacted"] else "masked full arena")
        print(f"superstep {svc.stats.supersteps:3d}: "
              f"{d['A']}/{d['G']} slots active "
              f"(occupancy {d['occupancy']:.2f}) — {mode}")

    done = svc.completed
    for r in sorted(done, key=lambda r: r.uid):
        dist = r.visit_counts[-1]
        print(f"req {r.uid:2d}: actions={r.actions} "
              f"reward={sum(r.rewards):+.3f} supersteps={r.supersteps} "
              f"last visit dist={np.asarray(dist).tolist()}")
    s = svc.stats
    print(f"\n{s.completed} searches in {s.supersteps} supersteps "
          f"on executor={args.executor} "
          f"({s.compacted_supersteps} compacted, "
          f"avg occupancy {s.occupancy_sum / max(s.supersteps, 1):.2f}); "
          f"fused sim batches: {s.sim_batches} "
          f"(max {s.max_fused_rows} states/batch); "
          f"intree={s.t_intree:.3f}s host={s.t_host:.3f}s sim={s.t_sim:.3f}s")


if __name__ == "__main__":
    main()
