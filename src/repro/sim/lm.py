"""LM-decode-as-tree-search: the environment and simulation backend that
plan over next-token actions with a language model.

Moved out of examples/lm_mcts_decode.py and made a served workload:

  * LMTreeEnv — states are token sequences (stored in the StateTable);
    actions are the top-F tokens the LM proposes at each node; the
    horizon caps tree depth.
  * LMContinuationBackend — simulation value = the LM's mean token
    log-prob over a greedy continuation, but BATCHED: every row's
    continuation decodes together through ONE ContinuousBatcher pool
    (serving/batcher.py, the continuous-batching substrate) instead of
    the old example's per-row sequential forward loop.  The batcher's
    pool size IS the LM microbatch knob the service_nn_backend_lm_*
    BENCH rows sweep.

Determinism: the batcher's decode is greedy and its pool schedule is a
pure function of the submitted request stream, so evaluate() is exactly
reproducible for a given states batch — the property the executor
matrix's bit-identity legs rest on.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.serving.batcher import ContinuousBatcher, Request

__all__ = ["MAXLEN", "LMTreeEnv", "LMContinuationBackend"]

MAXLEN = 48


class LMTreeEnv:
    """Token-sequence environment over a (smoke) LM.

    Served end to end via ``SearchClient(env, sim_backend=...)`` — see
    examples/lm_mcts_decode.py for the decode loop over SearchHandle
    moves.
    """

    state_dtype = np.float32

    def __init__(self, cfg, params, fanout: int = 6, horizon: int = 5):
        import jax

        from repro.models import lm

        self.cfg, self.params = cfg, params
        self.F, self.horizon = fanout, horizon
        self.state_shape = (MAXLEN + 1,)   # [len, tokens...]
        self.max_actions = fanout
        self._fwd = jax.jit(
            lambda p, t: lm.forward(cfg, p, t, impl="naive")[0])

    def initial_state(self, seed: int) -> np.ndarray:
        s = np.zeros(MAXLEN + 1, np.float32)
        s[0] = 1
        s[1] = 1 + seed % 7
        return s

    def tokens(self, state: np.ndarray) -> np.ndarray:
        n = int(state[0])
        return np.asarray(state[1 : 1 + n], np.int64)

    def top_actions(self, state: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        t = jnp.asarray(self.tokens(state))[None]
        logits = np.asarray(self._fwd(self.params, t))[0, -1]
        return np.argsort(-logits)[: self.F]

    def num_actions(self, state: np.ndarray) -> int:
        return 0 if int(state[0]) >= MAXLEN - self.horizon else self.F

    def step(self, state: np.ndarray, a: int):
        tok = int(self.top_actions(state)[a])
        s = state.copy()
        n = int(s[0])
        s[1 + n] = tok
        s[0] = n + 1
        return s, 0.0, int(s[0]) >= MAXLEN - self.horizon


class LMContinuationBackend:
    """Simulation = greedy LM continuation scored by mean log-prob,
    decoded for ALL rows concurrently through one ContinuousBatcher pool.

    ``pool_size`` is the LM serving microbatch: rows beyond it queue and
    admit continuously as earlier continuations finish (the batcher's
    slot-wise admission), so a G×p simulation batch costs
    ceil(B / pool_size) waves of `horizon` decode steps instead of B
    sequential full-forward loops.
    """

    def __init__(self, env: LMTreeEnv, pool_size: int = 8,
                 impl: str = "naive", metrics=None):
        self.env = env
        self._uid = itertools.count()
        self.batcher = ContinuousBatcher(
            env.cfg, env.params, pool_size=pool_size,
            max_seq=MAXLEN + env.horizon + 2, impl=impl,
            record_logprobs=True, metrics=metrics)

    def bind_metrics(self, metrics) -> None:
        self.batcher.bind_metrics(metrics)

    def evaluate(self, states: np.ndarray):
        B = len(states)
        reqs = [Request(uid=next(self._uid),
                        prompt=self.env.tokens(states[i]).astype(np.int32),
                        max_new_tokens=self.env.horizon)
                for i in range(B)]
        self.batcher.completed = []
        for r in reqs:
            self.batcher.submit(r)
        done = self.batcher.run(
            max_steps=self.batcher.decode_steps + (B + 2) * self.env.horizon)
        assert len(done) == B, (
            f"LM continuation pool drained {len(done)}/{B} rows")
        by_uid = {r.uid: r for r in done}
        vals = np.asarray(
            [np.float32(sum(by_uid[r.uid].logprobs) / self.env.horizon)
             for r in reqs], np.float32)
        return vals, None
