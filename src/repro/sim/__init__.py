"""repro.sim — the simulation serving subsystem.

The paper's CPU/FPGA split keeps Simulation on the host workers; this
package makes that side a real subsystem: SimServer microbatches every
caller's rows behind one jitted forward (priority-classed admission
window, fixed-shape padding, non-blocking submit/collect),
SimCache/CachedSimBackend short-circuit re-expanded positions, and
sim.lm serves LM-decode-as-tree-search through the continuous batcher.

Wire any of them in with ``SearchClient(env, sim_backend=...)``.

LM pieces (LMTreeEnv, LMContinuationBackend) are imported lazily — they
pull in the model stack, which non-LM serving paths never need.
"""

from repro.sim.cache import CachedSimBackend, SimCache
from repro.sim.server import PRIORITY_CLASSES, PendingBatch, SimServer

__all__ = [
    "CachedSimBackend", "LMContinuationBackend", "LMTreeEnv",
    "PRIORITY_CLASSES", "PendingBatch", "SimCache", "SimServer",
]

_LM_NAMES = ("LMTreeEnv", "LMContinuationBackend", "MAXLEN")


def __getattr__(name):
    if name in _LM_NAMES:
        from repro.sim import lm

        return getattr(lm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
