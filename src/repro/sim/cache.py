"""Value/prior transposition cache for the simulation serving layer.

Re-expanded positions skip inference entirely: tree-parallel MCTS with
subtree reuse and multi-slot self-play re-evaluates the same positions
constantly (every reroot re-expands the committed child's subtree, and
G concurrent self-play games walk overlapping openings), so a small LRU
in front of the NN backend converts that redundancy into cache hits.

Keying: entries are keyed by the raw BYTES of the state row, not by
StateTable node ids — node ids are slot-local and recycled across
flush/reroot/compaction, so state content is the only transposition
identity that is stable across slots, pools, and re-expansions of the
same position.  (For Gomoku the row embeds player-to-move, so the
canonical perspective is part of the key for free.)

Hit/miss/evict counters live in the MetricsRegistry (``sim_cache_*``);
``bind_metrics`` rebinds them onto a client's registry after
construction (SearchClient does this for any ``sim_backend`` that
exposes the hook).
"""

from __future__ import annotations

import collections
from typing import Optional

import numpy as np

from repro.obs.metrics import NULL_REGISTRY

__all__ = ["SimCache", "CachedSimBackend"]


class SimCache:
    """Bounded LRU: state-content bytes -> (value, priors-row | None).

    Stored results are copies and returned as-is, so a hit is
    bit-identical to the cold evaluate that populated it.
    """

    def __init__(self, capacity: int = 4096, metrics=None):
        if capacity <= 0:
            raise ValueError(f"cache capacity must be positive: {capacity}")
        self.capacity = int(capacity)
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self.bind_metrics(metrics)

    def bind_metrics(self, metrics) -> None:
        reg = NULL_REGISTRY if metrics is None else metrics
        self._m_hits = reg.counter(
            "sim_cache_hits_total", "sim-cache lookups served from cache")
        self._m_miss = reg.counter(
            "sim_cache_misses_total", "sim-cache lookups sent to inference")
        self._m_evict = reg.counter(
            "sim_cache_evictions_total", "sim-cache LRU evictions")
        self._m_size = reg.gauge(
            "sim_cache_entries", "sim-cache resident entries")

    @staticmethod
    def key(state: np.ndarray) -> bytes:
        return np.ascontiguousarray(state).tobytes()

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: bytes) -> Optional[tuple]:
        hit = self._entries.get(key)
        if hit is None:
            self._m_miss.inc()
            return None
        self._entries.move_to_end(key)
        self._m_hits.inc()
        return hit

    def put(self, key: bytes, value, prior) -> None:
        if key in self._entries:
            self._entries.move_to_end(key)
        self._entries[key] = (
            np.float32(value),
            None if prior is None else np.array(prior, copy=True))
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self._m_evict.inc()
        self._m_size.set(len(self._entries))


class _PendingCached:
    """Ticket from CachedSimBackend.submit(): the per-row hit results
    plus the inner backend's in-flight handle for the miss rows."""

    __slots__ = ("keys", "hits", "miss_idx", "miss_states", "inner", "n")

    def __init__(self, keys, hits, miss_idx, miss_states, inner, n):
        self.keys = keys
        self.hits = hits                # row index -> (value, prior) | None
        self.miss_idx = miss_idx
        self.miss_states = miss_states
        self.inner = inner              # ticket | token | None
        self.n = n


class CachedSimBackend:
    """SimulationBackend wrapper: hits skip inference entirely; misses go
    to the inner backend as one batch.  Keeps the non-blocking
    submit/collect split when the inner backend has one (SimServer), so
    a caching server still overlaps device work with batch assembly.

    Caching is semantics-free when the inner backend's per-row results
    are batch-composition independent (SimServer pads every microbatch
    to a fixed shape precisely so this holds): cache-on and cache-off
    runs return bit-identical values/priors for every request stream —
    pinned by tests/test_executor_matrix.py's NN differential leg.
    """

    def __init__(self, inner, capacity: int = 4096, metrics=None):
        self.inner = inner
        self.cache = SimCache(capacity, metrics)

    def bind_metrics(self, metrics) -> None:
        self.cache.bind_metrics(metrics)
        if hasattr(self.inner, "bind_metrics"):
            self.inner.bind_metrics(metrics)

    # ---- non-blocking split ----
    def submit(self, states: np.ndarray, priority: Optional[str] = None):
        states = np.asarray(states)
        keys = [SimCache.key(states[i]) for i in range(len(states))]
        hits = [self.cache.get(k) for k in keys]
        miss_idx = [i for i, h in enumerate(hits) if h is None]
        miss_states = states[np.asarray(miss_idx)] if miss_idx else None
        inner = None
        if miss_idx:
            if callable(getattr(self.inner, "submit", None)):
                inner = self.inner.submit(miss_states, priority=priority)
            elif callable(getattr(self.inner, "dispatch", None)):
                inner = self.inner.dispatch(miss_states)
            # else: evaluate-only inner — computed at collect()
        return _PendingCached(keys, hits, miss_idx, miss_states, inner,
                              len(states))

    def collect(self, pending: _PendingCached):
        values = np.zeros(pending.n, np.float32)
        priors = None

        def _prior_row(row, pr):
            nonlocal priors
            if pr is None:
                return
            if priors is None:
                priors = np.zeros((pending.n, len(pr)),
                                  np.asarray(pr).dtype)
            priors[row] = pr

        if pending.miss_idx:
            if callable(getattr(self.inner, "collect", None)) \
                    and pending.inner is not None:
                mv, mp = self.inner.collect(pending.inner)
            elif callable(getattr(self.inner, "finalize", None)):
                mv, mp = self.inner.finalize(pending.inner,
                                             pending.miss_states)
            else:
                mv, mp = self.inner.evaluate(pending.miss_states)
            for j, row in enumerate(pending.miss_idx):
                pr = None if mp is None else mp[j]
                values[row] = mv[j]
                _prior_row(row, pr)
                self.cache.put(pending.keys[row], mv[j], pr)
        for row, hit in enumerate(pending.hits):
            if hit is not None:
                values[row] = hit[0]
                _prior_row(row, hit[1])
        return values, priors

    # ---- blocking protocol surface ----
    def evaluate(self, states: np.ndarray):
        return self.collect(self.submit(states))
