"""SimServer — microbatched policy/value inference behind one jitted
forward.

The paper's Fig. 5 observation made operational: per-worker batch-1 DNN
inference leaves throughput on the table, so the serving layer owns ONE
admission window and coalesces every caller's simulation rows —
cross-pool fused evaluates, overlap-mode gang submits, plain per-pool
supersteps — into fixed-shape microbatches before they reach the model.

Mechanics:

  * admission window — submitted rows queue per priority class
    (interactive > batch > self-play, FIFO within a class); a microbatch
    flushes as soon as ``max_batch`` rows are queued, and ``poll()``
    flushes a partial batch once the oldest row has waited ``max_wait``.
    ``collect()`` force-flushes whatever its ticket still needs, so a
    synchronous caller never deadlocks on the window.
  * fixed-shape padding — every microbatch is padded (with copies of its
    first row) to exactly ``max_batch`` rows before dispatch, so the
    jitted forward compiles ONCE and, more importantly, each row's
    result is independent of which other rows shared its batch.  That
    batch-composition independence is what makes the transposition cache
    (sim.cache) and the cross-pool coalescing semantics-free: cache-on /
    cache-off and any submit interleaving return bit-identical per-row
    results (tests/test_sim.py, tests/test_executor_matrix.py).
  * non-blocking split — ``submit`` returns a ticket after (at most)
    dispatching full microbatches; for backends exposing the
    dispatch/finalize split (envs.policy_net.NNSimBackend) the device
    programs are in flight while later submits still assemble.
    ``collect`` redeems the ticket; ``evaluate`` is submit + collect,
    keeping the plain SimulationBackend protocol.

Telemetry (``sim_server_*``) lands in the MetricsRegistry passed at
construction or bound later via ``bind_metrics`` (SearchClient binds its
own registry onto any sim backend exposing the hook).
"""

from __future__ import annotations

import collections
import time
from typing import Optional

import numpy as np

from repro.obs.metrics import NULL_REGISTRY

__all__ = ["PRIORITY_CLASSES", "PendingBatch", "SimServer"]

#: admission order: interactive rows pack into a microbatch before batch
#: rows, which pack before self-play rows
PRIORITY_CLASSES = ("interactive", "batch", "self-play")


class PendingBatch:
    """Ticket from SimServer.submit(); redeem with SimServer.collect()."""

    __slots__ = ("n", "values", "priors", "filled")

    def __init__(self, n: int):
        self.n = n
        self.values = np.zeros(n, np.float32)
        self.priors = None           # allocated at first prior-bearing row
        self.filled = 0

    @property
    def ready(self) -> bool:
        return self.filled >= self.n


class _Micro:
    """One flushed microbatch: padded states, in-flight device token (for
    dispatch-capable backends), and each real row's destination."""

    __slots__ = ("states", "n_real", "dst", "token")

    def __init__(self, states, n_real, dst, token):
        self.states = states
        self.n_real = n_real
        self.dst = dst               # [(ticket, row_in_ticket), ...]
        self.token = token


class SimServer:
    def __init__(self, backend, max_batch: int = 64,
                 max_wait_us: float = 200.0,
                 default_priority: str = "batch", metrics=None):
        if max_batch <= 0:
            raise ValueError(f"max_batch must be positive: {max_batch}")
        if default_priority not in PRIORITY_CLASSES:
            raise ValueError(
                f"unknown priority class {default_priority!r}: one of "
                f"{PRIORITY_CLASSES}")
        self.backend = backend
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_us) * 1e-6
        self.default_priority = default_priority
        # per-class FIFO of (state_row, ticket, row_in_ticket, t_arrival)
        self._queues = {c: collections.deque() for c in PRIORITY_CLASSES}
        self._queued = 0
        self._micros: collections.deque = collections.deque()
        self._can_dispatch = callable(getattr(backend, "dispatch", None)) \
            and callable(getattr(backend, "finalize", None))
        self.bind_metrics(metrics)

    def bind_metrics(self, metrics) -> None:
        reg = NULL_REGISTRY if metrics is None else metrics
        self._m_batches = reg.counter(
            "sim_server_batches_total", "microbatches dispatched")
        self._m_rows = {c: reg.counter(
            "sim_server_rows_total", "simulation rows admitted",
            priority=c) for c in PRIORITY_CLASSES}
        self._m_fill = reg.histogram(
            "sim_server_batch_fill", "real rows per dispatched microbatch")
        self._m_queue = reg.gauge(
            "sim_server_queue_depth", "rows waiting in the admission window")
        self._m_partial = reg.counter(
            "sim_server_partial_flushes_total",
            "microbatches flushed below max_batch (window close / collect)")

    # ---- protocol: non-blocking split ----
    def submit(self, states: np.ndarray,
               priority: Optional[str] = None) -> PendingBatch:
        """Enqueue a batch of simulation rows; returns the ticket.  Full
        microbatches are dispatched before returning (device work starts
        now for dispatch-capable backends); partial tails stay queued for
        later callers to pack into."""
        if priority is None:
            priority = self.default_priority
        if priority not in PRIORITY_CLASSES:
            raise ValueError(
                f"unknown priority class {priority!r}: one of "
                f"{PRIORITY_CLASSES}")
        states = np.asarray(states)
        ticket = PendingBatch(len(states))
        q = self._queues[priority]
        now = time.perf_counter()
        for i in range(len(states)):
            q.append((states[i], ticket, i, now))
        self._queued += len(states)
        self._m_rows[priority].inc(len(states))
        while self._queued >= self.max_batch:
            self._flush()
        self._m_queue.set(self._queued)
        return ticket

    def collect(self, ticket: PendingBatch):
        """Redeem a ticket: finalize in-flight microbatches (dispatch
        order) and force-flush any of the ticket's rows still queued.
        Returns (values [n], priors [n, A] | None)."""
        while not ticket.ready:
            if self._micros:
                self._finalize(self._micros.popleft())
            elif self._queued:
                self._flush()            # partial, padded to max_batch
            else:
                raise RuntimeError(
                    "collect() on a ticket with no queued or in-flight "
                    "rows — was it already collected?")
        self._m_queue.set(self._queued)
        return ticket.values, ticket.priors

    def poll(self) -> None:
        """Close the admission window if due: dispatch full microbatches,
        and flush a partial one once the oldest queued row has waited
        max_wait.  For callers that submit from an event loop; the
        superstep-driven serving path closes windows via collect()."""
        while self._queued >= self.max_batch:
            self._flush()
        heads = [q[0][3] for q in self._queues.values() if q]
        if heads and time.perf_counter() - min(heads) >= self.max_wait_s:
            self._flush()
        self._m_queue.set(self._queued)

    # ---- protocol: blocking compatibility surface ----
    def evaluate(self, states: np.ndarray):
        return self.collect(self.submit(states))

    # ---- internals ----
    def _flush(self) -> None:
        """Assemble one microbatch (priority order, FIFO within class),
        pad it to max_batch with copies of its first row — always a
        valid state, and row independence keeps real rows unaffected —
        and start the backend forward."""
        rows, dst = [], []
        for cls in PRIORITY_CLASSES:
            q = self._queues[cls]
            while q and len(rows) < self.max_batch:
                state, ticket, i, _ = q.popleft()
                rows.append(state)
                dst.append((ticket, i))
        if not rows:
            return
        self._queued -= len(rows)
        n_real = len(rows)
        if n_real < self.max_batch:
            rows.extend([rows[0]] * (self.max_batch - n_real))
            self._m_partial.inc()
        states = np.stack(rows)
        token = self.backend.dispatch(states) if self._can_dispatch else None
        self._micros.append(_Micro(states, n_real, dst, token))
        self._m_batches.inc()
        self._m_fill.observe(n_real)

    def _finalize(self, micro: _Micro) -> None:
        if self._can_dispatch:
            values, priors = self.backend.finalize(micro.token, micro.states)
        else:
            values, priors = self.backend.evaluate(micro.states)
        for j, (ticket, row) in enumerate(micro.dst):
            ticket.values[row] = values[j]
            if priors is not None:
                if ticket.priors is None:
                    ticket.priors = np.zeros(
                        (ticket.n, priors.shape[1]), priors.dtype)
                ticket.priors[row] = priors[j]
            ticket.filled += 1
