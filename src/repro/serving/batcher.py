"""Continuous-batching serving loop for the LM simulation backends.

The paper measures "simulation requests per second" — with LM backends
that means batched decode throughput under a live request stream.  This
module is the serving substrate: a fixed pool of B slots over ONE
preallocated cache (so the jitted decode step never retraces), with

  * slot-wise admission: new requests prefill into a free slot's cache
    region while other slots keep decoding (continuous batching);
  * per-slot position tracking and eviction on EOS/max-tokens;
  * deterministic greedy decoding (swap in a sampler as needed);
  * backpressure: with ``max_pending`` set, a submit that would overgrow
    the waiting queue makes the SUBMITTER pay service time (it steps the
    pool until the backlog fits) instead of growing an unbounded queue —
    no request is ever dropped;
  * telemetry via obs.metrics: pool occupancy and queue depth gauges,
    admission/completion/eviction counters (``serving_*``).

Prefill uses the single-sequence path (B=1 rows are written into the
slot), so admission cost is O(prompt) and does not stall the pool more
than one step.  On a real pod the same loop runs with the serve-layout
shardings from launch/specs.py (2D TP; see EXPERIMENTS §Perf-C).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm, steps
from repro.obs.metrics import NULL_REGISTRY


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the batcher:
    tokens: list = dataclasses.field(default_factory=list)
    logprobs: list = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    done_at: float = 0.0


def _logprob(logits_row: np.ndarray, tok: int) -> float:
    """Log-probability of one token under a logits row (host-side)."""
    l = np.asarray(logits_row, np.float32)
    return float(l[tok] - np.logaddexp.reduce(l))


class ContinuousBatcher:
    def __init__(self, cfg, params, pool_size: int = 8, max_seq: int = 256,
                 impl: str = "naive", max_pending: Optional[int] = None,
                 record_logprobs: bool = False, metrics=None):
        self.cfg, self.params = cfg, params
        self.B, self.max_seq = pool_size, max_seq
        self.max_pending = max_pending
        self.record_logprobs = record_logprobs
        self.caches = lm.init_caches(cfg, pool_size, max_seq)
        # scratch single-slot cache for admissions, allocated once: prefill
        # is functional (returns a fresh cache), so the zeroed scratch is
        # never mutated and one allocation serves every admission.
        self._scratch = lm.init_caches(cfg, 1, max_seq)
        self._decode = jax.jit(steps.make_decode_step(cfg, impl=impl))
        self._prefill_one = jax.jit(
            steps.make_prefill_step(cfg, impl=impl))
        self.slots: list[Optional[Request]] = [None] * pool_size
        self.pos = np.zeros(pool_size, np.int64)       # next position per slot
        self.cur_tok = np.zeros((pool_size, 1), np.int32)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.decode_steps = 0
        self.bind_metrics(metrics)

    def bind_metrics(self, metrics) -> None:
        reg = NULL_REGISTRY if metrics is None else metrics
        self._m_occupancy = reg.gauge(
            "serving_pool_occupancy", "occupied decode slots / pool size")
        self._m_queue = reg.gauge(
            "serving_queue_depth", "requests waiting for a decode slot")
        self._m_admitted = reg.counter(
            "serving_admitted_total", "requests prefilled into a slot")
        self._m_completed = reg.counter(
            "serving_completed_total", "requests finished decoding")
        self._m_evicted = {reason: reg.counter(
            "serving_evictions_total", "slot evictions by cause",
            reason=reason) for reason in ("max_tokens", "eos", "max_seq")}

    def _occupied(self) -> int:
        return sum(s is not None for s in self.slots)

    def _set_gauges(self) -> None:
        self._m_occupancy.set(self._occupied() / self.B)
        self._m_queue.set(len(self.queue))

    # ---- admission ----
    def submit(self, req: Request):
        req.submitted_at = time.perf_counter()
        self.queue.append(req)
        # backpressure: never drop — the submitter drives the pool until
        # its request fits the waiting-queue bound
        if self.max_pending is not None:
            while len(self.queue) > self.max_pending:
                if not self.step():
                    break
        self._set_gauges()

    def _admit(self):
        for slot in range(self.B):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            # single-row prefill into the preallocated scratch cache (left
            # untouched — prefill returns its updated copy), then splice
            logits, one = self._prefill_one(self.params, prompt, self._scratch)
            self.caches = _splice_slot(self.caches, one, slot)
            self.slots[slot] = req
            self.pos[slot] = len(req.prompt)
            self.cur_tok[slot, 0] = int(jnp.argmax(logits[0]))
            req.tokens.append(int(self.cur_tok[slot, 0]))
            if self.record_logprobs:
                req.logprobs.append(
                    _logprob(np.asarray(logits[0]), req.tokens[-1]))
            self._m_admitted.inc()
        self._set_gauges()

    # ---- decode tick ----
    def step(self):
        self._admit()
        if not any(s is not None for s in self.slots):
            return False
        # ragged continuous batching: per-row positions (idle slots pinned
        # to 0; their outputs are ignored)
        occupied = np.array([s is not None for s in self.slots])
        posv = jnp.asarray(np.where(occupied, self.pos, 0), jnp.int32)
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.cur_tok), posv)
        self.decode_steps += 1
        host_logits = np.asarray(logits) if self.record_logprobs else None
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.tokens.append(tok)
            if host_logits is not None:
                req.logprobs.append(_logprob(host_logits[slot], tok))
            self.pos[slot] += 1
            self.cur_tok[slot, 0] = tok
            reason = None
            if len(req.tokens) >= req.max_new_tokens:
                reason = "max_tokens"
            elif req.eos_id is not None and tok == req.eos_id:
                reason = "eos"
            elif self.pos[slot] >= self.max_seq - 1:
                reason = "max_seq"
            if reason is not None:
                req.done_at = time.perf_counter()
                self.completed.append(req)
                self.slots[slot] = None
                self._m_completed.inc()
                self._m_evicted[reason].inc()
        self._set_gauges()
        return True

    def run(self, max_steps: int = 1000):
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.decode_steps < max_steps:
            self.step()
        return self.completed


def _splice_slot(pool, one, slot):
    """Write the single-row cache `one` into row `slot` of the pool cache."""
    def sp(dst, src):
        if dst.ndim >= 2 and src.shape[0] == dst.shape[0] \
                and src.shape[1] == 1 and dst.shape[1] > 1:
            # stacked leading dim [R, B, ...]
            return dst.at[:, slot].set(src[:, 0])
        return dst
    return jax.tree.map(sp, pool, one)
