"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-1B; unverified]."""

from repro.models.config import LayerSpec, ModelConfig

_BLK = LayerSpec(kind="attn", window=None, mlp="dense")

CONFIG = ModelConfig(
    name="llama3.2-1b",
    d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=128256,
    groups=(((_BLK,), 16),),
    rope_theta=500000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    groups=(((_BLK,), 2),),
    rope_theta=500000.0, tie_embeddings=True, dtype="float32",
)
