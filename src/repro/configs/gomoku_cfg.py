"""Paper benchmark b: Gomoku 6x6 — F=36, D=5, X=48K, expand-all + DNN
simulation (paper §V-A)."""

from repro.core.tree import TreeConfig

TREE = TreeConfig(X=48_000, F=36, D=5, beta=5.0, vl_mode="wu",
                  score_fn="puct", leaf_mode="unexpanded", expand_all=True)

TREE_SMALL = TreeConfig(X=1024, F=36, D=5, beta=5.0, vl_mode="wu",
                        score_fn="puct", leaf_mode="unexpanded",
                        expand_all=True)
