"""Architecture registry + assigned input shapes.

Each assigned arch has its own module exporting CONFIG (exact published
numbers) and SMOKE (reduced same-family config for CPU tests).  The MCTS
benchmark configs of the paper itself live in pong.py / gomoku_cfg.py.
"""

from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = (
    "paligemma_3b",
    "recurrentgemma_9b",
    "gemma3_12b",
    "starcoder2_3b",
    "llama3_2_1b",
    "granite_3_8b",
    "mamba2_2_7b",
    "whisper_small",
    "deepseek_v3_671b",
    "mixtral_8x22b",
)

# canonical ids as given in the assignment (dashes/dots)
CANON = {
    "paligemma-3b": "paligemma_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "gemma3-12b": "gemma3_12b",
    "starcoder2-3b": "starcoder2_3b",
    "llama3.2-1b": "llama3_2_1b",
    "granite-3-8b": "granite_3_8b",
    "mamba2-2.7b": "mamba2_2_7b",
    "whisper-small": "whisper_small",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mixtral-8x22b": "mixtral_8x22b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def normalize(arch: str) -> str:
    return CANON.get(arch, arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{normalize(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


# mostly-local hybrids that run long_500k despite a minority of global
# layers (DESIGN.md §4: gemma3 keeps 1-in-6 global layers with a sharded
# full-length KV; the 5-in-6 local layers bound the rest)
LONG_CONTEXT_ALLOW = {"gemma3-12b"}


def cell_supported(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Is (arch, shape) a runnable cell?  (DESIGN.md §Arch-applicability)."""
    if (shape.name == "long_500k" and not cfg.supports_long_context()
            and cfg.name not in LONG_CONTEXT_ALLOW):
        return False, "pure full-attention stack: 500k decode out of contract"
    return True, ""
