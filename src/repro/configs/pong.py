"""Paper benchmark a: Atari Pong — F=6, D=9, X=56K (paper §V-A)."""

from repro.core.tree import TreeConfig

TREE = TreeConfig(X=56_000, F=6, D=9, beta=1.0, vl_mode="wu",
                  score_fn="uct", leaf_mode="partial")

# reduced config for CPU smoke tests / quick benchmarks
TREE_SMALL = TreeConfig(X=2048, F=6, D=9, beta=1.0, vl_mode="wu",
                        score_fn="uct", leaf_mode="partial")
