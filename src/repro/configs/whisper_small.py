"""whisper-small [audio] — 12L d_model=768 12H d_ff=3072 vocab=51865 —
enc-dec; conv/audio frontend STUB: input_specs() provides 1500 precomputed
frame embeddings [arXiv:2212.04356; unverified].

Decoder: causal self-attn + cross-attn to the encoder output.  Skips
long_500k (full attention).  Decode shapes exercise the decoder with
cached cross-KV.
"""

from repro.models.config import EncoderConfig, LayerSpec, ModelConfig

_DEC = LayerSpec(kind="attn", window=None, mlp="dense", cross_attn=True)

CONFIG = ModelConfig(
    name="whisper-small",
    d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51865,
    groups=(((_DEC,), 12),),
    norm="layernorm", act="gelu", gated_mlp=False,
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
)

SMOKE = ModelConfig(
    name="whisper-small-smoke",
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512,
    groups=(((_DEC,), 2),),
    norm="layernorm", act="gelu", gated_mlp=False,
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=2, n_frames=32), dtype="float32",
)
