"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 [hf:ibm-granite/granite-3.0-2b-base; hf]."""

from repro.models.config import LayerSpec, ModelConfig

_BLK = LayerSpec(kind="attn", window=None, mlp="dense")

CONFIG = ModelConfig(
    name="granite-3-8b",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab=49155,          # padded to 49408 for TP divisibility
    groups=(((_BLK,), 40),),
    rope_theta=10000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-3-8b-smoke",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, vocab=515,              # odd vocab: exercises padding
    groups=(((_BLK,), 2),),
    tie_embeddings=True, dtype="float32",
)
