"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attn, pattern 2 recurrent : 1 local-attn
(window 2048) [arXiv:2402.19427; unverified].

38 = 12 x (rec, rec, attn) + (rec, rec) tail.  Runs long_500k: RG-LRU
state is O(1), attention windows are bounded.
"""

from repro.models.config import LayerSpec, ModelConfig

_REC = LayerSpec(kind="rglru", mlp="dense")
_ATT = LayerSpec(kind="attn", window=2048, mlp="dense")

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    groups=(((_REC, _REC, _ATT), 12), ((_REC, _REC), 1)),
    rope_theta=10000.0, tie_embeddings=True, embed_scale=True,
    lru_width=4096,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512,
    groups=(((_REC, _REC,
              LayerSpec(kind="attn", window=16, mlp="dense")), 1),
            ((_REC,), 1)),
    tie_embeddings=True, embed_scale=True, lru_width=64, dtype="float32",
)
