"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local(1024):global, 128k context
[hf:google/gemma-3-1b-pt; unverified].

long_500k note (DESIGN.md §4): 40/48 layers are 1024-window local; the 8
global layers keep a full-length KV cache, sharded over the mesh — we run
the cell and report its memory in the dry-run.
"""

from repro.models.config import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=1024, mlp="dense")
_GLOBAL = LayerSpec(kind="attn", window=None, mlp="dense")

CONFIG = ModelConfig(
    name="gemma3-12b",
    d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    groups=(((_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL), 8),),
    rope_theta=1000000.0, tie_embeddings=True, embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    groups=(((LayerSpec(kind="attn", window=16, mlp="dense"),
              LayerSpec(kind="attn", window=None, mlp="dense")), 2),),
    tie_embeddings=True, embed_scale=True, dtype="float32",
)
