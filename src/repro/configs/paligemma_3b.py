"""paligemma-3b [vlm] — 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP + gemma backbone [arXiv:2407.07726; hf].

The SigLIP vision tower is a STUB per the assignment: input_specs()
provides 256 precomputed patch embeddings at d_model (post-projector);
they form a bidirectional prefix ahead of the causal text tokens.
"""

from repro.models.config import LayerSpec, ModelConfig

_BLK = LayerSpec(kind="attn", window=None, mlp="dense")

CONFIG = ModelConfig(
    name="paligemma-3b",
    d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216,
    groups=(((_BLK,), 18),),
    rope_theta=10000.0, tie_embeddings=True, embed_scale=True,
    vlm_patches=256,
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke",
    d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512,
    groups=(((_BLK,), 2),),
    tie_embeddings=True, embed_scale=True, vlm_patches=8, dtype="float32",
)
