"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384,
MoE 8 experts top-2, SWA window 4096 [arXiv:2401.04088; hf].

SWA makes every layer's KV bounded => runs long_500k with ring caches.
8 experts don't divide the 16-way model axis; sharding falls back to the
expert-FFN "mlp" dim (models/sharding.py).
"""

from repro.models.config import LayerSpec, ModelConfig

_BLK = LayerSpec(kind="attn", window=4096, mlp="moe")

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768,
    groups=(((_BLK,), 56),),
    rope_theta=1000000.0, tie_embeddings=True,
    n_experts=8, top_k=2, moe_d_ff=16384,
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    groups=(((LayerSpec(kind="attn", window=16, mlp="moe"),), 2),),
    tie_embeddings=True,
    n_experts=4, top_k=2, moe_d_ff=128, dtype="float32",
)
