"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060; unverified].

Pure SSD stack: no attention, no separate MLP (the SSD block carries the
expansion).  O(1) decode state => runs long_500k.
"""

from repro.models.config import LayerSpec, ModelConfig

_SSD = LayerSpec(kind="ssd", mlp="none")

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    d_model=2560, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280,
    groups=(((_SSD,), 64),),
    tie_embeddings=True,
    ssd_state=128, ssd_headdim=64, ssd_expand=2, conv_width=4,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke",
    d_model=64, n_heads=1, n_kv_heads=1, head_dim=16,
    d_ff=0, vocab=512,
    groups=(((_SSD,), 2),),
    tie_embeddings=True,
    ssd_state=16, ssd_headdim=16, ssd_expand=2, conv_width=4,
    ssd_chunk=32, dtype="float32",
)
