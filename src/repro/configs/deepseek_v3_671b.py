"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff(moe)=2048
vocab=129280 — MLA (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 /
v 128), 1 shared + 256 routed top-8, MTP head [arXiv:2412.19437; hf].

First 3 layers are dense (d_ff = 18432, the published dense-layer width;
the assignment's d_ff=2048 is the per-expert MoE width).  Skips long_500k
(MLA compresses the cache but attention is global).  FSDP sharding + the
adafactor optimizer are required for HBM fit — see launch/dryrun.py.
"""

from repro.models.config import LayerSpec, ModelConfig

_DENSE = LayerSpec(kind="attn", window=None, mlp="dense")
_MOE = LayerSpec(kind="attn", window=None, mlp="moe")

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, vocab=129280,
    groups=(((_DENSE,), 3), ((_MOE,), 58)),
    rope_theta=10000.0, tie_embeddings=True,
    attn_impl="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    mtp=True,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke",
    d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512,
    groups=(((_DENSE,), 1), ((_MOE,), 2)),
    tie_embeddings=True,
    attn_impl="mla",
    q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16,
    n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=64,
    mtp=True, dtype="float32",
)
