"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, layernorm + plain GeLU MLP [arXiv:2402.19173; hf]."""

from repro.models.config import LayerSpec, ModelConfig

_BLK = LayerSpec(kind="attn", window=None, mlp="dense")

CONFIG = ModelConfig(
    name="starcoder2-3b",
    d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab=49152,
    groups=(((_BLK,), 30),),
    norm="layernorm", act="gelu", gated_mlp=False,
    rope_theta=100000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke",
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512,
    groups=(((_BLK,), 2),),
    norm="layernorm", act="gelu", gated_mlp=False,
    tie_embeddings=True, dtype="float32",
)
