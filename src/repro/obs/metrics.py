"""MetricsRegistry — counters / gauges / histograms, Prometheus text out.

The numeric half of the observability layer (obs.trace is the temporal
half): every serving layer registers its telemetry here — queue depth
and smoothed load per bucket, fused-batch sizes, admission waits,
eviction / retirement / expiry counts, expansion batch calls, compaction
decisions — and ``render()`` emits one snapshot in Prometheus exposition
format (the text format every scrape pipeline ingests):

    # HELP service_queue_depth requests queued, not yet admitted
    # TYPE service_queue_depth gauge
    service_queue_depth{bucket="X512_D8_Fp8"} 3

Zero dependencies, get-or-create semantics: two layers asking for the
same (name, labels) share the one time series, so the scheduler core and
its pools can instrument independently without coordination.  Metric
objects are plain attribute bumps (`inc`/`set`/`observe`) — cheap enough
for per-superstep call sites.

NULL_REGISTRY is the disabled path: the same surface returning shared
no-op metric objects, `enabled` False, `render()` empty.  Layers default
to it; the `service_obs_overhead` BENCH row pins the resulting
disabled-path cost at well under the 2% CI gate.
"""

from __future__ import annotations

from bisect import bisect_left

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullRegistry",
    "NULL_METRIC", "NULL_REGISTRY",
]

# powers-of-two style buckets suit the layer's unit mix (ticks, rows)
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


class Counter:
    """Monotonically increasing count."""

    kind = "counter"
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.value = 0

    def inc(self, n=1):
        self.value += n

    def rows(self):
        yield "", self.value


class Gauge:
    """A value that goes up and down."""

    kind = "gauge"
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.value = 0

    def set(self, v):
        self.value = v

    def inc(self, n=1):
        self.value += n

    def dec(self, n=1):
        self.value -= n

    def rows(self):
        yield "", self.value


class Histogram:
    """Cumulative-bucket histogram (Prometheus `le` convention: each
    exported bucket counts observations <= its upper bound, closed by
    the implicit +Inf bucket; `_sum` and `_count` ride along)."""

    kind = "histogram"
    __slots__ = ("name", "labels", "bounds", "counts", "sum", "count")

    def __init__(self, name: str, labels: dict, buckets=DEFAULT_BUCKETS):
        self.name, self.labels = name, labels
        self.bounds = tuple(sorted(buckets))
        self.counts = [0] * (len(self.bounds) + 1)   # +1: the +Inf bucket
        self.sum = 0
        self.count = 0

    def observe(self, v):
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def rows(self):
        cum = 0
        for bound, n in zip(self.bounds, self.counts):
            cum += n
            yield f'_bucket:le="{_fmt(bound)}"', cum
        yield '_bucket:le="+Inf"', self.count
        yield "_sum", self.sum
        yield "_count", self.count


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == float("inf"):
            return "+Inf"
        return f"{v:g}"
    return str(v)


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n")


def _label_str(labels: dict, extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create registry of labelled metrics + Prometheus render."""

    enabled = True

    def __init__(self):
        # name -> {sorted-label-items -> metric}; insertion order kept so
        # snapshots are stable run to run
        self._metrics: dict[str, dict] = {}
        self._kinds: dict[str, str] = {}
        self._helps: dict[str, str] = {}

    # ---- registration (get-or-create) ----
    def _get(self, kind: str, name: str, help: str, labels: dict, **kw):
        known = self._kinds.get(name)
        if known is None:
            self._kinds[name] = kind
            self._helps[name] = help
            self._metrics[name] = {}
        elif known != kind:
            raise ValueError(
                f"metric {name!r} already registered as {known}, "
                f"requested {kind}")
        elif help and not self._helps[name]:
            self._helps[name] = help
        series = self._metrics[name]
        key = tuple(sorted(labels.items()))
        metric = series.get(key)
        if metric is None:
            metric = series[key] = _KINDS[kind](name, labels, **kw)
        return metric

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_BUCKETS, **labels) -> Histogram:
        return self._get("histogram", name, help, labels, buckets=buckets)

    # ---- read-out ----
    def get(self, name: str, **labels):
        """The metric at (name, labels), or None (tests / dashboards)."""
        return self._metrics.get(name, {}).get(tuple(sorted(labels.items())))

    def snapshot(self) -> dict:
        """{name: {label_str: value}} for counters/gauges, histogram
        series expanded — a dict mirror of render() for programmatic
        consumers."""
        out: dict = {}
        for name in self._metrics:
            series = out.setdefault(name, {})
            for metric in self._metrics[name].values():
                for suffix, value in metric.rows():
                    extra = ""
                    if ":" in suffix:
                        suffix, extra = suffix.split(":", 1)
                    series[f"{name}{suffix}"
                           f"{_label_str(metric.labels, extra)}"] = value
        return out

    def render(self) -> str:
        """One Prometheus-exposition-format snapshot of every series."""
        lines = []
        for name in self._metrics:
            if self._helps[name]:
                lines.append(f"# HELP {name} {self._helps[name]}")
            lines.append(f"# TYPE {name} {self._kinds[name]}")
            for metric in self._metrics[name].values():
                for suffix, value in metric.rows():
                    extra = ""
                    if ":" in suffix:
                        suffix, extra = suffix.split(":", 1)
                    lines.append(
                        f"{name}{suffix}"
                        f"{_label_str(metric.labels, extra)} {_fmt(value)}")
        return "\n".join(lines) + ("\n" if lines else "")


class _NullMetric:
    """Shared no-op metric: every mutator a pass."""

    __slots__ = ()

    def inc(self, n=1):
        pass

    def dec(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass


NULL_METRIC = _NullMetric()


class NullRegistry:
    """The disabled path: same surface, no-op metrics, empty render."""

    enabled = False

    def counter(self, name, help="", **labels):
        return NULL_METRIC

    def gauge(self, name, help="", **labels):
        return NULL_METRIC

    def histogram(self, name, help="", buckets=DEFAULT_BUCKETS, **labels):
        return NULL_METRIC

    def get(self, name, **labels):
        return None

    def snapshot(self) -> dict:
        return {}

    def render(self) -> str:
        return ""


NULL_REGISTRY = NullRegistry()
