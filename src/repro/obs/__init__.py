"""Observability layer: tracing + metrics for the search service.

Zero-dependency (stdlib only), threaded through every serving layer —
SearchClient / SchedulerCore / ArenaPool / ExpansionEngine /
CompactionSession all accept an optional tracer + registry and default
to the shared no-op instances, so the disabled path costs a handful of
no-op calls per superstep (pinned by the `service_obs_overhead` BENCH
row and its CI gate).

  obs.trace    Tracer — nested spans (per-superstep phases: select /
               expand / simulate / backup / compact-gather /
               compact-scatter, with explicit block_until_ready fencing
               when tracing is live so device time is attributed
               honestly) + async request-lifecycle spans (submit ->
               admit -> supersteps -> move-commit -> result / cancel /
               evict), recorded into a lock-free drop-oldest ring and
               exported as Chrome-trace / Perfetto JSON
               (``Tracer.export()`` -> open at ui.perfetto.dev).
  obs.metrics  MetricsRegistry — labelled counters / gauges /
               histograms (queue depth, smoothed load, fused-batch
               rows, admission wait, evictions, retirements, expired
               results, expansion batch calls, compaction decisions)
               with a Prometheus-exposition-format text snapshot.

Entry points: ``SearchClient(trace=True, metrics=True)`` then
``client.trace_export("trace.json")`` / ``client.metrics()``; or build
a ``Tracer``/``MetricsRegistry`` yourself and hand the same instances to
several components.  Bit-identity of traced vs untraced runs across
every executor is pinned in tests/test_executor_matrix.py.
"""

from repro.obs.metrics import (
    NULL_METRIC, NULL_REGISTRY, Counter, Gauge, Histogram, MetricsRegistry,
    NullRegistry,
)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Tracer", "NullTracer", "Span", "NULL_TRACER",
    "MetricsRegistry", "NullRegistry", "Counter", "Gauge", "Histogram",
    "NULL_METRIC", "NULL_REGISTRY",
]
