"""Tracer — nested spans over a lock-free ring buffer, Chrome-trace out.

The paper's 35x in-tree and 3x system numbers rest on Fig. 8-style phase
breakdowns: knowing, per superstep, where the time went on each side of
the CPU/accelerator boundary.  This module is the measurement substrate
for the serving stack — zero dependencies (stdlib only), cheap enough to
stay wired into every layer, and exportable to the trace viewers people
actually use:

  Tracer      records four event kinds into a fixed-capacity ring
              (drop-oldest, no locks — a single writer index is the whole
              synchronization story, which is all the single-threaded
              serving loop needs while staying safe under the GIL):

                * complete spans   — begin()/end() or the span() context
                  manager; per-track LIFO nesting is enforced, so a
                  malformed instrumentation site fails loudly instead of
                  exporting garbage;
                * instants         — point events (admit / move-commit /
                  cancel / retire);
                * async spans      — async_begin()/async_end() pairs keyed
                  by (cat, name, id): request lifecycles that span many
                  ticks and interleave arbitrarily;
                * track metadata   — track() names a timeline (scheduler,
                  one per arena pool) and returns its tid.

  export()    Chrome-trace / Perfetto JSON ({"traceEvents": [...]}):
              load the file at ui.perfetto.dev or chrome://tracing.
              Timestamps are microseconds relative to Tracer creation.

  NULL_TRACER the disabled path: same surface, every method a no-op,
              `enabled` False so call sites can gate explicit
              block_until_ready fences on tracing being live.  Layers
              default to it, which is what keeps the disabled-path
              overhead at a handful of no-op calls per superstep
              (measured by the `service_obs_overhead` BENCH row).

The clock is injectable (``clock_ns``) so tests can pin nesting and
ordering deterministically.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Optional

__all__ = ["Span", "Tracer", "NullTracer", "NULL_TRACER"]


class Span:
    """An open span: the token begin() hands out and end() consumes.
    Carries everything the eventual "X" record needs except duration."""

    __slots__ = ("name", "cat", "tid", "ts", "args", "depth")

    def __init__(self, name, cat, tid, ts, args, depth):
        self.name, self.cat, self.tid = name, cat, tid
        self.ts, self.args, self.depth = ts, args, depth


class _SpanCtx:
    """``with tracer.span(...)`` — allocation-light begin/end pairing."""

    __slots__ = ("_tracer", "_name", "_cat", "_tid", "_args", "_tok")

    def __init__(self, tracer, name, cat, tid, args):
        self._tracer, self._name, self._cat = tracer, name, cat
        self._tid, self._args = tid, args

    def __enter__(self):
        self._tok = self._tracer.begin(self._name, cat=self._cat,
                                       tid=self._tid, **self._args)
        return self._tok

    def __exit__(self, *exc):
        self._tracer.end(self._tok)


def _jsonable(v):
    """Coerce an args value to something json.dumps accepts (numpy
    scalars arrive from metric sites; stringify anything exotic)."""
    if isinstance(v, (bool, str)):
        return v
    if isinstance(v, float):
        return v
    try:
        return int(v)
    except (TypeError, ValueError):
        pass
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class Tracer:
    """Nested-span tracer over a fixed-capacity drop-oldest ring."""

    enabled = True

    def __init__(self, capacity: int = 1 << 16,
                 clock_ns: Optional[Callable[[], int]] = None, pid: int = 0):
        if capacity <= 0:
            raise ValueError(f"tracer capacity must be positive: {capacity}")
        self.capacity = capacity
        self.pid = pid
        self._clock = time.perf_counter_ns if clock_ns is None else clock_ns
        self._t0 = self._clock()
        # the ring: one preallocated slot list + a single monotonically
        # increasing write index (lock-free single-writer discipline)
        self._ring: list = [None] * capacity
        self._n = 0
        self._stacks: dict[int, list] = {}   # tid -> open-span stack
        self._tracks: dict[str, int] = {}    # track name -> tid
        self._next_tid = 0
        # metadata events (process/track names): tiny, never dropped
        self._meta: list[dict] = [{
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": "search-service"},
        }]

    # ---- clock / buffer ----
    def _now_us(self) -> float:
        return (self._clock() - self._t0) / 1e3

    def _push(self, ev: dict):
        self._ring[self._n % self.capacity] = ev
        self._n += 1

    @property
    def dropped(self) -> int:
        """Events evicted by the ring (oldest-first)."""
        return max(0, self._n - self.capacity)

    # ---- tracks ----
    def track(self, name: str) -> int:
        """Get-or-create a named timeline; returns its tid.  Tracks keep
        each pool's phase spans properly nested even when a gang tick
        interleaves several pools' begin/finish halves."""
        tid = self._tracks.get(name)
        if tid is None:
            tid = self._tracks[name] = self._next_tid
            self._next_tid += 1
            self._meta.append({
                "ph": "M", "name": "thread_name", "pid": self.pid,
                "tid": tid, "args": {"name": name}})
            self._meta.append({
                "ph": "M", "name": "thread_sort_index", "pid": self.pid,
                "tid": tid, "args": {"sort_index": tid}})
        return tid

    # ---- complete spans ----
    def begin(self, name: str, cat: str = "", tid: int = 0, **args) -> Span:
        stack = self._stacks.setdefault(tid, [])
        tok = Span(name, cat, tid, self._now_us(), args, len(stack))
        stack.append(tok)
        return tok

    def end(self, tok: Span):
        stack = self._stacks.get(tok.tid)
        assert stack and stack[-1] is tok, (
            f"span end out of order on track {tok.tid}: ending "
            f"{tok.name!r} but "
            f"{stack[-1].name if stack else '<empty>'!r} is open")
        stack.pop()
        self._push({
            "ph": "X", "name": tok.name, "cat": tok.cat, "pid": self.pid,
            "tid": tok.tid, "ts": tok.ts,
            "dur": self._now_us() - tok.ts, "args": tok.args})

    def span(self, name: str, cat: str = "", tid: int = 0,
             **args) -> _SpanCtx:
        return _SpanCtx(self, name, cat, tid, args)

    def open_depth(self, tid: int = 0) -> int:
        """How many spans are currently open on a track (tests)."""
        return len(self._stacks.get(tid, ()))

    # ---- instants ----
    def instant(self, name: str, cat: str = "", tid: int = 0, **args):
        self._push({
            "ph": "i", "s": "t", "name": name, "cat": cat, "pid": self.pid,
            "tid": tid, "ts": self._now_us(), "args": args})

    # ---- async spans (request lifecycles spanning many ticks) ----
    def async_begin(self, name: str, aid, cat: str = "", tid: int = 0,
                    **args):
        self._push({
            "ph": "b", "id": int(aid), "name": name, "cat": cat,
            "pid": self.pid, "tid": tid, "ts": self._now_us(),
            "args": args})

    def async_end(self, name: str, aid, cat: str = "", tid: int = 0,
                  **args):
        self._push({
            "ph": "e", "id": int(aid), "name": name, "cat": cat,
            "pid": self.pid, "tid": tid, "ts": self._now_us(),
            "args": args})

    # ---- read-out ----
    def events(self) -> list[dict]:
        """Retained events, oldest first (metadata excluded)."""
        if self._n <= self.capacity:
            return [e for e in self._ring[: self._n]]
        cut = self._n % self.capacity
        return self._ring[cut:] + self._ring[:cut]

    def clear(self):
        self._ring = [None] * self.capacity
        self._n = 0
        self._stacks.clear()

    def export(self, path=None) -> dict:
        """Chrome-trace JSON: ``{"traceEvents": [...]}``.  Open the file
        (or a json.dump of the returned dict) at https://ui.perfetto.dev
        or chrome://tracing.  With ``path`` the JSON is also written
        there."""
        events = []
        for ev in self._meta + self.events():
            ev = dict(ev)
            if ev.get("args"):
                ev["args"] = {k: _jsonable(v) for k, v in ev["args"].items()}
            events.append(ev)
        out = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(out, f)
        return out


class _NullSpanCtx:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        pass


_NULL_SPAN_CTX = _NullSpanCtx()


class NullTracer:
    """The disabled path: the Tracer surface with every method a no-op.
    Layers hold one of these (the shared NULL_TRACER) when tracing is
    off, so instrumentation sites stay unconditional and the per-
    superstep cost is a handful of attribute lookups."""

    enabled = False
    capacity = 0
    dropped = 0

    def track(self, name: str) -> int:
        return 0

    def begin(self, name, cat="", tid=0, **args):
        return None

    def end(self, tok):
        pass

    def span(self, name, cat="", tid=0, **args) -> _NullSpanCtx:
        return _NULL_SPAN_CTX

    def open_depth(self, tid: int = 0) -> int:
        return 0

    def instant(self, name, cat="", tid=0, **args):
        pass

    def async_begin(self, name, aid, cat="", tid=0, **args):
        pass

    def async_end(self, name, aid, cat="", tid=0, **args):
        pass

    def events(self) -> list:
        return []

    def clear(self):
        pass

    def export(self, path=None) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_TRACER = NullTracer()
