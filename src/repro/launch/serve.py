"""Serving launcher: batched LM inference = the paper's Simulation backend.

Stands up an LM (smoke or full config), prefills a batch of prompts, then
serves decode steps — reporting the paper's system-throughput metric
(simulation requests per second, one request = one batched-decode slot).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 16 --prefill 64 --tokens 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm, steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--prefill", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(cfg, key)
    impl = "naive" if args.prefill <= 512 else "blockwise"
    prefill = jax.jit(steps.make_prefill_step(cfg, impl=impl))
    decode = jax.jit(steps.make_decode_step(cfg, impl=impl))

    max_seq = args.prefill + args.tokens + 8
    caches = lm.init_caches(cfg, args.batch, max_seq)
    tokens = jax.random.randint(key, (args.batch, args.prefill), 0, cfg.vocab)
    kw = {}
    if cfg.vlm_patches:
        kw["patches"] = jnp.zeros((args.batch, cfg.vlm_patches, cfg.d_model),
                                  jnp.float32)
    if cfg.encoder is not None:
        kw["frames"] = jnp.zeros(
            (args.batch, cfg.encoder.n_frames, cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, caches = prefill(params, tokens, caches, **kw)
    jax.block_until_ready(logits)
    t1 = time.time()

    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    for i in range(args.tokens):
        logits, caches = decode(params, caches, tok,
                                jnp.asarray(args.prefill + i))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    t2 = time.time()

    n_req = args.batch * args.tokens
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prefill} in "
          f"{t1-t0:.3f}s; {n_req} decode requests in {t2-t1:.3f}s "
          f"=> {n_req/(t2-t1):,.0f} req/s", flush=True)
    return n_req / (t2 - t1)


if __name__ == "__main__":
    main()
