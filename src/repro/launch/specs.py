"""Per-cell input specs and sharding rules for the dry-run / launcher.

input_specs() returns ShapeDtypeStruct stand-ins for every input of the
step being lowered (weak-type-correct, shardable, no device allocation).
rules_for() picks the sharding rules for an (arch, shape) cell; the
optimizer choice (adamw vs adafactor) and FSDP flag are part of the arch's
deployment config (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm, sharding as sh
from repro.models.config import ModelConfig
from repro.optim import make_optimizer

SDS = jax.ShapeDtypeStruct

# archs whose param+optimizer footprint needs ZeRO-3-style sharding
FSDP_ARCHS = {"recurrentgemma-9b", "gemma3-12b", "granite-3-8b",
              "deepseek-v3-671b", "mixtral-8x22b"}
# archs whose optimizer state must be factored to fit HBM
ADAFACTOR_ARCHS = {"deepseek-v3-671b", "mixtral-8x22b"}


def optimizer_for(cfg: ModelConfig):
    name = "adafactor" if cfg.name in ADAFACTOR_ARCHS else "adamw"
    return name, make_optimizer(name, lr=3e-4, warmup=100, total=10_000)


def rules_for(cfg: ModelConfig, shape: configs.ShapeSpec,
              optimized: bool = False) -> sh.Rules:
    fsdp = cfg.name in FSDP_ARCHS
    if shape.name == "long_500k":
        # batch=1: shard the sequence/cache-length dim instead
        base = sh.Rules(batch=(), seq=("pod", "data"), fsdp_params=fsdp)
    elif shape.kind == "decode":
        # HBM-fit iteration (EXPERIMENTS §Perf-0): a 32k KV cache with only
        # batch sharding leaves up to 40 GB/device (granite); sharding the
        # cache length over the model axis restores fit — softmax partials
        # combine with tiny [B,H,1] collectives.
        base = sh.Rules(batch=("pod", "data"), seq=("model",),
                        fsdp_params=fsdp)
    else:
        base = sh.Rules(batch=("pod", "data"), seq=(), fsdp_params=fsdp)
    if optimized:
        base = OPTIMIZED_RULES.get((cfg.name, shape.name), base)
    return base


# §Perf hillclimb layouts (EXPERIMENTS.md documents hypothesis -> result):
OPTIMIZED_RULES = {
    # sequence-parallel prefill: 24 heads don't divide the model axis, so
    # head-sharding falls back and GSPMD all-reduces every projection;
    # sharding the sequence instead keeps projections local and turns the
    # attention exchange into O(KV) per layer.
    ("starcoder2-3b", "prefill_32k"): sh.Rules(
        batch=("pod", "data"), seq=("model",), fsdp_params=False),
    # shard_map expert path (see models/moe.py — iteration 1 with plain
    # sharding constraints was refuted; iteration 2 forces local dispatch).
    ("mixtral-8x22b", "train_4k"): sh.Rules(
        batch=("pod", "data"), seq=(), fsdp_params=True,
        moe_shard_map=True),
    # same shard_map expert-path as mixtral; 256 experts would normally
    # shard over the model axis, which the shard_map dispatch cannot use —
    # shard the per-expert FFN dim instead (shard_experts=False).
    ("deepseek-v3-671b", "train_4k"): sh.Rules(
        batch=("pod", "data"), seq=(), fsdp_params=True,
        moe_shard_map=True, shard_experts=False),
    # 2D tensor-parallel serving: params sharded over (data x model) —
    # no per-step FSDP re-gather; cache sequence sharded over both axes;
    # batch replicated (decode is parameter/cache-bandwidth-bound).
    ("deepseek-v3-671b", "decode_32k"): sh.Rules(
        batch=(), seq=("data", "model"), model=("data", "model"),
        fsdp_params=False),
}


def config_for(cfg: ModelConfig, shape: configs.ShapeSpec,
               optimized: bool = False) -> ModelConfig:
    """Per-cell model-config overrides for the optimized runs."""
    import dataclasses as dc
    if optimized and cfg.attn_impl == "mla" and shape.kind == "decode":
        cfg = dc.replace(cfg, mla_absorb=True)   # absorbed-MLA decode
    return cfg


def token_specs(cfg: ModelConfig, shape: configs.ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        d = {"tokens": SDS((B, S), jnp.int32),
             "labels": SDS((B, S), jnp.int32),
             "mask": SDS((B, S), jnp.float32)}
    elif shape.kind == "prefill":
        d = {"tokens": SDS((B, S), jnp.int32)}
    else:  # decode: one token against a seq_len cache
        d = {"tokens": SDS((B, 1), jnp.int32)}
    if cfg.vlm_patches and shape.kind != "decode":
        d["patches"] = SDS((B, cfg.vlm_patches, cfg.d_model), jnp.float32)
    if cfg.encoder is not None and shape.kind != "decode":
        d["frames"] = SDS((B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    return d


def batch_spec_shardings(mesh, rules, cfg, shape, batch_specs):
    out = {}
    for k, v in batch_specs.items():
        if k in ("tokens", "labels", "mask"):
            ax = ("batch", "seq") if v.shape[1] > 1 else ("batch", None)
        elif k in ("patches", "frames"):
            ax = ("batch", "seq", "embed")
        else:
            ax = (None,) * len(v.shape)
        out[k] = jax.sharding.NamedSharding(
            mesh, sh.spec_for_act(mesh, rules, ax, v.shape))
    return out


# ----------------------------------------------------------------- caches

_CACHE_AXES = {
    "k": (None, "batch", "seq", "kv_heads", None),
    "v": (None, "batch", "seq", "kv_heads", None),
    "xk": (None, "batch", None, "kv_heads", None),
    "xv": (None, "batch", None, "kv_heads", None),
    "c": (None, "batch", "seq", None),            # MLA latent
    "state": (None, "batch", "heads", None, None),  # SSD
    "conv": (None, "batch", None, "mlp"),
    "h": (None, "batch", "mlp"),                  # RG-LRU
    "pos": (None,),
}


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int):
    return jax.eval_shape(
        lambda: lm.init_caches(cfg, batch, max_seq))


def cache_shardings(mesh, rules, cache_tree):
    def one(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        ax = _CACHE_AXES.get(name, (None,) * len(leaf.shape))
        ax = ax[: len(leaf.shape)]
        if len(ax) < len(leaf.shape):
            ax = ax + (None,) * (len(leaf.shape) - len(ax))
        return jax.sharding.NamedSharding(
            mesh, sh.spec_for_act(mesh, rules, ax, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


# -------------------------------------------------------------- opt state

def opt_state_shardings(mesh, rules, opt_name, axes_tree, param_shapes,
                        opt_shapes):
    """Shardings for optimizer state, derived from the param logical axes."""
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def pshard(ax, shp):
        return jax.sharding.NamedSharding(
            mesh, sh.spec_for_param(mesh, rules, ax, shp.shape))

    if opt_name == "adamw":
        m = jax.tree.map(pshard, axes_tree, param_shapes, is_leaf=is_ax)
        return {"m": m, "v": m}

    # adafactor: vr drops the last dim, vc the second-to-last
    def fshard(ax, pshp, st):
        if "vr" in st:
            return {
                "vr": jax.sharding.NamedSharding(
                    mesh, sh.spec_for_param(mesh, rules, ax[:-1],
                                            pshp.shape[:-1])),
                "vc": jax.sharding.NamedSharding(
                    mesh, sh.spec_for_param(
                        mesh, rules, ax[:-2] + ax[-1:],
                        pshp.shape[:-2] + pshp.shape[-1:])),
            }
        return {"v": jax.sharding.NamedSharding(
            mesh, sh.spec_for_param(mesh, rules, ax, pshp.shape))}

    stats = jax.tree.map(
        fshard, axes_tree, param_shapes, opt_shapes["stats"],
        is_leaf=is_ax)
    return {"stats": stats}


# ---------------------------------------------------------------- helpers

def bytes_of(tree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(tree))


def sharded_bytes_per_device(tree, shardings, mesh) -> int:
    """Exact per-device bytes given shapes + NamedShardings."""
    total = 0
    ndev = mesh.size

    def one(leaf, shd):
        nonlocal total
        shards = 1
        spec = shd.spec
        for i, s in enumerate(spec):
            if s is None:
                continue
            names = s if isinstance(s, tuple) else (s,)
            for n in names:
                shards *= mesh.shape[n]
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize // shards

    jax.tree.map(one, tree, shardings)
    return total
