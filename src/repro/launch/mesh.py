"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run forces 512 host devices before any
jax initialization; tests and benches see 1 device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (CPU tests: 1 device -> (1,1))."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return jax.make_mesh(shape, axes)


def serving_devices(n_shards: int) -> list:
    """Device assignment for the D-sharded serving arena (service/pool.py):
    shard d lives on ``jax.devices()[d % len(devices)]``.

    With fewer physical devices than shards the assignment wraps — the
    scheduler's D-way slot partition and placement logic are exercised
    either way, and on a multi-device host (or under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` in CI) each
    shard lands on its own device.  A FUNCTION for the same reason as the
    mesh builders: importing this module must never touch device state.
    """
    devs = jax.devices()
    return [devs[d % len(devs)] for d in range(max(1, int(n_shards)))]
