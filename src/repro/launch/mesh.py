"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run forces 512 host devices before any
jax initialization; tests and benches see 1 device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (CPU tests: 1 device -> (1,1))."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return jax.make_mesh(shape, axes)
