"""Parse collective traffic out of compiled (post-SPMD) HLO text.

cost_analysis() has no collective-bytes term, so the roofline's third term
comes from summing the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op in
compiled.as_text().
"""

from __future__ import annotations

import re
from collections import defaultdict

_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")
_OP_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|[a-z0-9]+\[[^\]]*\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")

_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
          "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
          "f64": 8}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt_, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt_]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op_name: result_bytes_total} plus 'total'.  '-done' ops are
    skipped (their '-start' counterpart carries the payload)."""
    out = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if f"{m.group('op')}-done" in line:
            continue
        out[m.group("op")] += _shape_bytes(m.group("rtype"))
    out["total"] = sum(v for k, v in out.items())
    return dict(out)


def count_ops(hlo_text: str) -> dict:
    c = defaultdict(int)
    for op in _OPS:
        c[op] = len(re.findall(rf"\b{op}(?:-start)?\(", hlo_text))
    # remat indicator: duplicated fusions
    c["fusion"] = hlo_text.count(" fusion(")
    return dict(c)
