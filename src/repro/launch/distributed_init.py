"""Multi-host initialization for real pod deployments.

On a TPU pod slice each host runs the same program; jax.distributed wires
them into one runtime.  This module is the entry shim the launch scripts
call before anything touches jax device state.  In the CPU container it
degrades to a no-op single-process world (the dry-run emulates the mesh
with --xla_force_host_platform_device_count instead).

Environment contract (set by launch/scripts/*.sh or the cluster manager):
  REPRO_COORDINATOR   host:port of process 0 (default localhost:9911)
  REPRO_NUM_PROCESSES world size (default 1)
  REPRO_PROCESS_ID    this host's rank (default 0)
"""

from __future__ import annotations

import os


def init_distributed() -> dict:
    num = int(os.environ.get("REPRO_NUM_PROCESSES", "1"))
    pid = int(os.environ.get("REPRO_PROCESS_ID", "0"))
    coord = os.environ.get("REPRO_COORDINATOR", "localhost:9911")
    if num > 1:
        import jax
        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=num,
            process_id=pid,
        )
    return {"num_processes": num, "process_id": pid, "coordinator": coord}


def is_primary() -> bool:
    return int(os.environ.get("REPRO_PROCESS_ID", "0")) == 0
