import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * builds the production mesh (16x16 single pod / 2x16x16 multi-pod),
  * lowers the jitted step (train_step for train_4k; prefill/serve_step
    for inference shapes) with ShapeDtypeStruct inputs + NamedShardings,
  * compiles, records memory_analysis / cost_analysis / collective bytes
    (parsed from post-SPMD HLO) + exact per-device param/opt/cache bytes,
  * writes one JSON artifact per cell to artifacts/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both [--smoke] [--out artifacts/dryrun]

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count on first init.  Do not import this module from tests.
"""

import argparse
import dataclasses
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import collectives, roofline, specs
from repro.launch.mesh import make_production_mesh
from repro.models import lm, sharding as sh, steps
from repro.models.config import param_count, active_param_count

HBM_PER_CHIP = 16 * 1024**3          # v5e
PEAK_FLOPS = 197e12                  # bf16 / chip
HBM_BW = 819e9                       # B/s / chip
ICI_BW = 50e9                        # B/s / link

NS = jax.sharding.NamedSharding


def lower_cell(cfg, shape, mesh, impl="blockwise", optimized=False):
    """Returns (lowered, meta) for one cell."""
    cfg = specs.config_for(cfg, shape, optimized)
    rules = specs.rules_for(cfg, shape, optimized)
    sh.set_context(mesh, rules)
    try:
        axes = lm.param_axes(cfg)
        pshapes = lm.param_shapes(cfg)
        pshard = sh.make_param_shardings(mesh, rules, axes, pshapes)
        tok = specs.token_specs(cfg, shape)
        tshard = specs.batch_spec_shardings(mesh, rules, cfg, shape, tok)
        meta = {"params_bytes_device": specs.sharded_bytes_per_device(
            pshapes, pshard, mesh)}

        if shape.kind == "train":
            opt_name, (opt_init, opt_update) = specs.optimizer_for(cfg)
            oshapes = jax.eval_shape(opt_init, pshapes)
            oshard = specs.opt_state_shardings(
                mesh, rules, opt_name, axes, pshapes, oshapes)
            meta["opt_bytes_device"] = specs.sharded_bytes_per_device(
                oshapes, oshard, mesh)
            meta["optimizer"] = opt_name
            train_step = steps.make_train_step(cfg, opt_update, impl=impl)

            def step(params, opt_state, step_no, batch):
                return train_step(params, opt_state, step_no, batch)

            jitted = jax.jit(
                step,
                in_shardings=(pshard, oshard, NS(mesh, sh.P()), tshard),
                out_shardings=(pshard, oshard, None),
            )
            lowered = jitted.lower(
                pshapes, oshapes, jax.ShapeDtypeStruct((), jnp.int32), tok)
            meta["_traceable"] = (step, (pshapes, oshapes,
                                         jax.ShapeDtypeStruct((), jnp.int32),
                                         tok))
        elif shape.kind == "prefill":
            cshapes = specs.cache_shapes(cfg, shape.global_batch, shape.seq_len)
            cshard = specs.cache_shardings(mesh, rules, cshapes)
            meta["cache_bytes_device"] = specs.sharded_bytes_per_device(
                cshapes, cshard, mesh)
            prefill = steps.make_prefill_step(cfg, impl=impl)
            extra_keys = [k for k in ("patches", "frames") if k in tok]

            def step(params, tokens, caches, *extras):
                kw = dict(zip(extra_keys, extras))
                return prefill(params, tokens, caches, **kw)

            jitted = jax.jit(
                step,
                in_shardings=(pshard, tshard["tokens"], cshard,
                              *[tshard[k] for k in extra_keys]),
                out_shardings=None,
            )
            lowered = jitted.lower(pshapes, tok["tokens"], cshapes,
                                   *[tok[k] for k in extra_keys])
            meta["_traceable"] = (step, (pshapes, tok["tokens"], cshapes,
                                         *[tok[k] for k in extra_keys]))
        else:  # decode
            cshapes = specs.cache_shapes(cfg, shape.global_batch, shape.seq_len)
            cshard = specs.cache_shardings(mesh, rules, cshapes)
            meta["cache_bytes_device"] = specs.sharded_bytes_per_device(
                cshapes, cshard, mesh)
            decode = steps.make_decode_step(cfg, impl=impl)
            jitted = jax.jit(
                decode,
                in_shardings=(pshard, cshard, tshard["tokens"],
                              NS(mesh, sh.P())),
                out_shardings=(None, cshard),
            )
            lowered = jitted.lower(
                pshapes, cshapes, tok["tokens"],
                jax.ShapeDtypeStruct((), jnp.int32))
            meta["_traceable"] = (decode, (pshapes, cshapes, tok["tokens"],
                                           jax.ShapeDtypeStruct((), jnp.int32)))
        return lowered, meta
    finally:
        sh.set_context(None)


def analyze(lowered, compiled, meta, cfg, shape, mesh) -> dict:
    chips = mesh.size
    rec = dict(meta)
    rec["mesh"] = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    rec["chips"] = chips

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        # cost_analysis of the SPMD executable is PER-DEVICE (verified:
        # 6ND/chips for dense archs); totals are derived.
        rec["hlo_flops_device"] = float(ca.get("flops", 0.0))
        rec["hlo_bytes_device"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = repr(e)
        rec["hlo_flops_device"] = rec["hlo_bytes_device"] = 0.0
    rec["hlo_flops"] = rec["hlo_flops_device"] * chips
    rec["hlo_bytes"] = rec["hlo_bytes_device"] * chips

    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = repr(e)

    hlo = compiled.as_text()
    # the compiled text is the one-device SPMD program: parsed collective
    # bytes are per-device traffic.  `collectives` counts loop bodies once;
    # `collectives_scaled` applies while-body trip multipliers.
    rec["collectives"] = collectives.collective_bytes(hlo)
    rec["collectives_scaled"] = roofline.scaled_collectives(hlo)
    rec["collective_ops"] = collectives.count_ops(hlo)
    rec["hlo_lines"] = hlo.count("\n")

    # trip-count-aware global flops/bytes from the jaxpr (see roofline.py)
    fn_args = meta.pop("_traceable", None)
    rec.pop("_traceable", None)
    if fn_args is not None:
        try:
            jc = roofline.jaxpr_costs(fn_args[0], *fn_args[1])
            rec["jaxpr_flops_global"] = float(jc.get("flops", 0))
            rec["jaxpr_bytes_global"] = float(jc.get("bytes", 0))
        except Exception as e:  # pragma: no cover
            rec["jaxpr_error"] = repr(e)

    # roofline terms (per-step seconds): per-device work over per-chip rate
    # == brief's total/(chips * rate).
    fit = rec.get("params_bytes_device", 0) + rec.get("opt_bytes_device", 0) \
        + rec.get("cache_bytes_device", 0)
    rec["state_bytes_device"] = fit
    rec["fits_hbm_state"] = bool(fit < HBM_PER_CHIP)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = active_param_count(cfg)
    rec["n_params"] = param_count(cfg)
    rec["n_active_params"] = n_active
    mult = 6 if shape.kind == "train" else 2
    rec["model_flops"] = float(mult * n_active * tokens)
    rec["tokens"] = tokens
    # roofline terms use the trip-count-corrected analyses; raw
    # cost_analysis numbers stay in the record for reference.
    jf = rec.get("jaxpr_flops_global", rec["hlo_flops"])
    jb = rec.get("jaxpr_bytes_global", rec["hlo_bytes"])
    rec["compute_s"] = jf / (chips * PEAK_FLOPS)
    rec["memory_s"] = max(jb / chips,
                          rec.get("state_bytes_device", 0)) / HBM_BW
    rec["collective_s"] = rec["collectives_scaled"]["total"] / ICI_BW
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: rec[k])
    rec["dominant"] = dom
    denom = rec.get("jaxpr_flops_global") or rec["hlo_flops"]
    rec["useful_flops_ratio"] = (
        rec["model_flops"] / denom if denom else 0.0)
    return rec


def run_cell(arch, shape_name, multi_pod, smoke=False,
             out_dir="artifacts/dryrun", optimized=False):
    cfg = configs.get_config(arch, smoke=smoke)
    shape = configs.SHAPES[shape_name]
    ok, why = configs.cell_supported(cfg, shape)
    tag = f"{configs.normalize(arch)}__{shape_name}__{'multi' if multi_pod else 'single'}"
    outp = pathlib.Path(out_dir)
    outp.mkdir(parents=True, exist_ok=True)
    rec = {"arch": cfg.name, "shape": shape_name,
           "multi_pod": multi_pod, "smoke": smoke, "optimized": optimized}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        (outp / f"{tag}.json").write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] {tag}: SKIPPED ({why})", flush=True)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, meta = lower_cell(cfg, shape, mesh, optimized=optimized)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec.update(analyze(lowered, compiled, meta, cfg, shape, mesh))
        rec["status"] = "ok"
        rec["lower_s"] = t1 - t0
        rec["compile_s"] = t2 - t1
        print(f"[dryrun] {tag}: OK lower={t1-t0:.1f}s compile={t2-t1:.1f}s "
              f"dom={rec['dominant']} flops={rec['hlo_flops']:.3e}", flush=True)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = repr(e)
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {tag}: ERROR {e!r}", flush=True)
    (outp / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf hillclimb layouts (specs.OPTIMIZED_RULES)")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = configs.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(configs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                rec = run_cell(arch, shp, mp, smoke=args.smoke,
                               out_dir=args.out, optimized=args.optimized)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors",
          flush=True)
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
