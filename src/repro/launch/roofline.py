"""Trip-count-aware cost analysis for the roofline.

Why this exists: XLA's cost_analysis() counts while/scan bodies ONCE, so a
48-layer scanned model reports ~1/48th of its real FLOPs, and the HLO-text
collective parse has the same blind spot.  Three analyses fix that:

1. jaxpr_costs(fn, *args): walks the closed jaxpr (GLOBAL, pre-SPMD
   shapes), multiplying through scan `length` params.  Counts
   - FLOPs: dot_general (2*batch*free_l*free_r*contract) + convolution,
     elementwise/reduce ops at 1 flop/elem — this includes remat recompute
     (the grad jaxpr materializes it) and is the honest "HLO_FLOPs";
   - fusion-optimistic bytes: operand+result bytes of memory-bound ops
     (dots, gathers/scatters, sorts, scan carries) — elementwise chains
     are assumed fused into their consumers, matching post-fusion HBM
     traffic far better than the unfused per-op sum.

2. scaled_collectives(hlo_text): builds the computation call graph of the
   compiled (post-SPMD, per-device) module and multiplies collective bytes
   inside while bodies by each loop's EXACT trip count — XLA annotates
   every while with backend_config known_trip_count, including nested
   attention-block loops.  Collective totals are therefore exact
   per-step per-device traffic.

3. Exact state-bytes-per-device from shardings (launch.specs).
"""

from __future__ import annotations

import re
from collections import defaultdict

import jax
import jax.extend  # noqa: F401  (jax.extend.core is not auto-imported)
import numpy as np

from repro.launch import collectives as coll

# ---------------------------------------------------------------- jaxpr

_DOT_PRIMS = {"dot_general"}
_CONV_PRIMS = {"conv_general_dilated"}
_MEM_PRIMS = {
    "dot_general", "conv_general_dilated", "gather", "scatter",
    "scatter-add", "scatter_add", "sort", "cumsum", "cumlogsumexp",
    "dynamic_slice", "dynamic_update_slice", "take", "argsort",
}


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _aval_elems(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    batch = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
    contract = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    lfree = int(np.prod([s for i, s in enumerate(lhs.shape)
                         if i not in lc and i not in lb]))
    rfree = int(np.prod([s for i, s in enumerate(rhs.shape)
                         if i not in rc and i not in rb]))
    return 2 * batch * contract * lfree * rfree


def _sub_jaxprs(params):
    out = []
    for v in params.values():
        if isinstance(v, jax.extend.core.ClosedJaxpr):
            out.append(v)
        elif hasattr(v, "jaxpr") and hasattr(v, "consts"):
            out.append(v)
        elif isinstance(v, jax.extend.core.Jaxpr):
            out.append(jax.extend.core.ClosedJaxpr(v, ()))
        elif isinstance(v, (tuple, list)):
            for e in v:
                if isinstance(e, jax.extend.core.ClosedJaxpr):
                    out.append(e)
                elif isinstance(e, jax.extend.core.Jaxpr):
                    out.append(jax.extend.core.ClosedJaxpr(e, ()))
    return out


def _walk(jaxpr, mult, acc):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        submult = mult
        if name == "scan":
            submult = mult * int(eqn.params.get("length", 1))
        elif name == "shard_map":
            # shard_map bodies trace with PER-DEVICE shapes; every device
            # executes the body, so global work = local x mesh size.
            m = eqn.params.get("mesh")
            try:
                sz = int(np.prod(list(dict(m.shape).values())))
            except Exception:
                sz = getattr(m, "size", 1)
            submult = mult * int(sz)
        elif name == "while":
            # only used by in-house kernels, not the LM stack; bodies are
            # data-dependent -> count once and flag.
            acc["unbounded_while"] += 1
        if name in _DOT_PRIMS:
            acc["flops"] += mult * _dot_flops(eqn)
            acc["bytes"] += mult * (
                sum(_aval_bytes(v.aval) for v in eqn.invars)
                + sum(_aval_bytes(v.aval) for v in eqn.outvars))
        elif name in _CONV_PRIMS:
            out = eqn.outvars[0].aval
            rhs = eqn.invars[1].aval
            ksize = int(np.prod(rhs.shape[:-1]))
            acc["flops"] += mult * 2 * _aval_elems(out) * ksize
            acc["bytes"] += mult * (
                sum(_aval_bytes(v.aval) for v in eqn.invars)
                + sum(_aval_bytes(v.aval) for v in eqn.outvars))
        elif name in _MEM_PRIMS:
            acc["bytes"] += mult * (
                sum(_aval_bytes(v.aval) for v in eqn.invars)
                + sum(_aval_bytes(v.aval) for v in eqn.outvars))
        else:
            # elementwise / reduce: 1 flop per output element, no bytes
            # (assumed fused).
            acc["flops"] += mult * sum(
                _aval_elems(v.aval) for v in eqn.outvars)
        for sub in _sub_jaxprs(eqn.params):
            if name == "scan":
                # scan carries cross HBM each iteration
                acc["bytes"] += submult * sum(
                    _aval_bytes(v.aval) for v in sub.jaxpr.invars)
            _walk(sub.jaxpr, submult, acc)
    return acc


def jaxpr_costs(fn, *args, **kw) -> dict:
    """Global (unpartitioned) trip-count-aware flops/bytes."""
    closed = jax.make_jaxpr(fn)(*args, **kw)
    acc = defaultdict(int)
    _walk(closed.jaxpr, 1, acc)
    return dict(acc)


# ------------------------------------------------- HLO collective scaling

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-_]+|[\w\.\-_]+)\s*\(")
_CALLEE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations|true_computation|"
    r"false_computation)=\{?(%[\w\.\-_]+|[\w\.\-_]+)")
_WHILE_BODY = re.compile(r"\bwhile\(.*body=(%[\w\.\-_]+|[\w\.\-_]+)")


def _split_computations(hlo_text: str) -> dict:
    """Computation headers sit at column 0, end with '{' and contain no
    ' = ' (op lines are indented and are assignments)."""
    comps, cur, buf = {}, None, []
    for line in hlo_text.splitlines():
        if (line and not line[0].isspace() and line.rstrip().endswith("{")
                and " = " not in line):
            m = _COMP_HDR.match(line)
            if m:
                cur = m.group(1).lstrip("%")
                buf = []
                comps[cur] = buf
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                buf.append(line)
    return comps


_TRIP_RE = re.compile(r'known_trip_count[^}]*?n["\':\s]+(\d+)')


def scaled_collectives(hlo_text: str, default_trip: int = 1) -> dict:
    """Exact per-step per-device collective bytes: while-body collectives
    are multiplied by each loop's known_trip_count annotation (nested
    loops compose multiplicatively along the call graph)."""
    comps = _split_computations(hlo_text)
    local = {name: coll.collective_bytes("\n".join(lines))
             for name, lines in comps.items()}
    # call edges: caller -> (callee, iteration multiplier)
    edges = defaultdict(list)
    n_unknown = 0
    for name, lines in comps.items():
        for line in lines:
            wb = _WHILE_BODY.search(line)
            body = wb.group(1).lstrip("%") if wb else None
            trip = None
            if body is not None:
                t = _TRIP_RE.search(line)
                if t:
                    trip = int(t.group(1))
                else:
                    trip = default_trip
                    n_unknown += 1
            for callee in _CALLEE.findall(line):
                callee = callee.lstrip("%")
                if callee in comps:
                    edges[name].append(
                        (callee, trip if callee == body else 1))

    called = {c for outs in edges.values() for c, _ in outs}
    roots = [c for c in comps if c not in called]
    mult = defaultdict(int)
    seen = set()

    def dfs(node, m):
        key = (node, m)
        if key in seen or len(seen) > 200_000:
            return
        seen.add(key)
        mult[node] = max(mult[node], m)
        for callee, k in edges.get(node, ()):
            dfs(callee, m * k)

    for r in roots:
        dfs(r, 1)

    out = defaultdict(int)
    for name, cb in local.items():
        m = max(1, mult.get(name, 1))
        for k, v in cb.items():
            if k != "total":
                out[k] += v * m
    out["total"] = sum(out.values())
    out["unannotated_whiles"] = n_unknown
    return dict(out)
