"""Training launcher (end-to-end driver, deliverable (b)).

Runs real steps on whatever devices exist (CPU here; the production mesh
path is exercised by dryrun.py).  Features: config-driven arch selection,
deterministic data pipeline with host prefetch, gradient-accumulation
microbatching, atomic+async checkpointing with restart-replay, optional
int8 gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import Prefetcher, SyntheticTokens
from repro.distributed import CheckpointManager
from repro.launch import specs
from repro.models import lm, steps
from repro.optim.compression import int8_roundtrip


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression (inter-pod trick)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    print(f"[train] {cfg.name}: {sum(np.prod(l.shape) for l in jax.tree.leaves(lm.param_shapes(cfg))):,} params")

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_name, (opt_init, opt_update) = specs.optimizer_for(cfg)
    opt_state = opt_init(params)
    train_step = jax.jit(steps.make_train_step(
        cfg, opt_update, microbatches=args.microbatches,
        compress_fn=int8_roundtrip if args.compress else None,
        impl="naive" if args.seq <= 512 else "blockwise"))

    start = 0
    mgr = None
    if args.ckpt:
        mgr = CheckpointManager(args.ckpt, keep_last=3, async_save=True)
        s, state, _ = mgr.restore_latest({"params": params, "opt": opt_state})
        if s is not None:
            start = s + 1
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {s}")

    src = SyntheticTokens(cfg.vocab, args.batch, args.seq, seed=args.seed)
    pf = Prefetcher(src, start_step=start)
    losses = []
    t0 = time.time()
    try:
        for _ in range(start, args.steps):
            step_i, batch = pf.next()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.vlm_patches:
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.vlm_patches, cfg.d_model), jnp.float32)
            if cfg.encoder is not None:
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder.n_frames, cfg.d_model),
                    jnp.float32)
            params, opt_state, metrics = train_step(
                params, opt_state, jnp.asarray(step_i), batch)
            if step_i % args.log_every == 0 or step_i == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append(loss)
                dt_ = time.time() - t0
                tok_s = (step_i - start + 1) * args.batch * args.seq / max(dt_, 1e-9)
                print(f"[train] step {step_i:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"tok/s {tok_s:9.0f}", flush=True)
            if mgr and step_i and step_i % args.ckpt_every == 0:
                mgr.save(step_i, {"params": params, "opt": opt_state})
        if mgr:
            mgr.save(args.steps - 1, {"params": params, "opt": opt_state})
            mgr.wait()
    finally:
        pf.close()
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
