"""SearchService — deprecated single-bucket wrapper over ArenaPool.

The service stack is client-first now (see service/client.py for the
layer map):

  client.py          SearchClient / SearchHandle — the public API:
                     opaque handles (done/result/cancel/moves), poll and
                     run_until instead of a drain-only run().
  scheduler_core.py  SchedulerCore + SchedulePolicy — global admission
                     across config buckets, deadline eviction, cold-pool
                     retirement, cross-pool fused Simulation batches.
  pool.py            ArenaPool — one bucket's G-slot arena + StateTables
                     + admission queue; the BSP superstep body
                     (Selection / Insertion / host expansion / fused
                     Simulation / BackUp) split at the Simulation
                     boundary so the core can batch across pools.
  frontend.py        ServiceFrontend — the pre-handle compatibility
                     adapter (submit returns the routed pool).
  this module        SearchService — ArenaPool under its historical name
                     and signature: the one-config service every legacy
                     test, bench and example was written against.  It
                     emits a one-time DeprecationWarning pointing at
                     SearchClient; the scheduler surface — submit/
                     superstep/run, stats, last_decision, exec — is
                     otherwise unchanged.

Mirrors serving/batcher.py's slot pattern one level up the stack: the
pool is a TreeArena of G slots instead of a KV-cache pool, a request is a
whole search instead of a prompt, and the decode tick is a BSP superstep
advancing EVERY occupied slot together, with all slots' simulation states
fused into ONE SimulationBackend.evaluate batch (the cross-request
analogue of the within-tree worker batching the paper's Fig. 5 measures).
See pool.py for the lifecycle and compaction details.
"""

from __future__ import annotations

import warnings

from repro.service.pool import (
    ArenaPool, SearchRequest, SearchResult, ServiceStats,
)

__all__ = ["ArenaPool", "SearchRequest", "SearchResult", "SearchService",
           "ServiceStats"]


class SearchService(ArenaPool):
    """G-slot multi-tree MCTS server for ONE TreeConfig — the deprecated
    single-bucket special case of the client/scheduler/pool stack.  New
    code should submit through service.client.SearchClient, which routes
    heterogeneous request configs, returns opaque SearchHandles, and
    schedules across buckets (policies, deadlines, retirement, cross-pool
    fused simulation)."""

    _warned = False      # one-time deprecation notice per process

    def __init__(self, *args, **kwargs):
        if not SearchService._warned:
            SearchService._warned = True
            warnings.warn(
                "SearchService is deprecated: use "
                "repro.service.client.SearchClient (opaque SearchHandles, "
                "poll/run_until, schedule policies) — SearchService remains "
                "as a single-bucket compatibility wrapper only",
                DeprecationWarning, stacklevel=2)
        super().__init__(*args, **kwargs)
