"""Search scheduler — continuous batching of MCTS requests over tree slots.

Mirrors serving/batcher.py's slot pattern, one level up the stack: the
pool is a TreeArena of G slots instead of a KV-cache pool, a request is a
whole search (env seed + superstep budget + number of moves) instead of a
prompt, and the decode tick is a BSP superstep advancing EVERY occupied
slot through Selection / Insertion / host expansion / Simulation / BackUp
together.  The Simulation phase is fused: the p simulation states of every
active slot are concatenated into ONE SimulationBackend.evaluate call, so
an expensive backend (NN / LM inference) always sees the largest batch the
current load allows — the cross-request analogue of the within-tree worker
batching the paper's Fig. 5 measures.

Lifecycle of a request:
  queued -> admitted into a free slot (fresh tree + ST, root = seed state)
         -> superstepped until its per-move budget / node cap / saturation
         -> move committed (robust child), then either
              * evicted with its action trace + root visit distributions, or
              * advanced in place: core.reroot extracts the chosen child's
                subtree (statistics preserved) and the search continues on
                the same slot for its next move.

Active-slot compaction: idle slots execute masked device work under the
uniform arena program — fine at high occupancy, wasteful at low.  Below an
occupancy threshold the scheduler gathers the A active slots into a dense
sub-arena (padded to the next power of two so the device program cache
stays bounded), runs every device phase on the sub-arena, and scatters the
results back (executor.gather_sub / scatter_sub).  Per-slot arithmetic is
position-independent, so masked and compacted execution are bit-identical.

Determinism: with a deterministic SimulationBackend the per-slot tree
evolution is bit-identical to a single-tree TreeParallelMCTS run of the
same request (tests/test_service.py) — scheduling changes WHEN a tree's
supersteps happen, never what they compute.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

from repro.core import fixedpoint as fx
from repro.core import reroot
from repro.core.expand import ExpansionEngine
from repro.core.mcts import Environment, SimulationBackend
from repro.core.state_table import StateTable
from repro.core.tree import NULL, TreeConfig
from repro.service.arena import make_arena_executor


@dataclasses.dataclass
class SearchRequest:
    """One user search: plan `moves` actions from the seed state, spending
    up to `budget` supersteps of p simulations per move."""

    uid: int
    seed: int
    budget: int = 16
    moves: int = 1
    keep_tree: bool = False      # attach the final tree snapshot to the result
    submitted_at: float = 0.0


@dataclasses.dataclass
class SearchResult:
    uid: int
    actions: list = dataclasses.field(default_factory=list)
    rewards: list = dataclasses.field(default_factory=list)
    visit_counts: list = dataclasses.field(default_factory=list)  # per move, [F]
    supersteps: int = 0
    terminal: bool = False
    tree_snapshot: Optional[dict] = None
    submitted_at: float = 0.0
    done_at: float = 0.0


@dataclasses.dataclass
class _Slot:
    req: SearchRequest
    res: SearchResult
    root_state: np.ndarray
    moves_done: int = 0
    move_supersteps: int = 0
    prev_size: int = 1


@dataclasses.dataclass
class ServiceStats:
    supersteps: int = 0
    admitted: int = 0
    completed: int = 0
    sim_rows: int = 0            # fused simulation-batch rows evaluated
    sim_batches: int = 0         # evaluate() calls (one per superstep)
    max_fused_rows: int = 0
    compacted_supersteps: int = 0  # supersteps run on a gathered sub-arena
    occupancy_sum: float = 0.0     # sum of per-superstep A/G (avg = /supersteps)
    t_intree: float = 0.0        # select + insert + finalize + backup
    t_host: float = 0.0          # ST / env expansion + scheduling bookkeeping
    t_expand: float = 0.0        # expansion-engine share of t_host
    t_sim: float = 0.0


class SearchService:
    """G-slot multi-tree MCTS server (one host, one device program/phase)."""

    def __init__(
        self,
        cfg: TreeConfig,
        env: Environment,
        sim: SimulationBackend,
        G: int,
        p: int,
        executor: str = "faithful",
        alternating_signs: bool = False,
        reuse_subtree: bool = True,
        compact_threshold: float = 0.0,
        expansion: str = "loop",
    ):
        self.cfg, self.env, self.sim = cfg, env, sim
        self.G, self.p = G, p
        self.alternating_signs = alternating_signs
        self.reuse_subtree = reuse_subtree
        # host-expansion engine: "loop" per-worker env.step, "vector" ONE
        # flattened step_batch over all slots' pending expansions, "pool"
        # the process-pool scalar fallback (core.expand) — bit-identical
        self.expander = ExpansionEngine(env, expansion)
        # occupancy A/G at or below this gathers active slots into a dense
        # sub-arena for the device phases.  Opt-in (0.0 = always masked):
        # BENCH_service.json shows the per-superstep gather/scatter costs
        # more than the masked work it saves on this CPU container; raise
        # it when the arena lives on a real device or X grows
        self.compact_threshold = compact_threshold
        self.exec = make_arena_executor(cfg, G, executor)
        self.sts = [StateTable(cfg.X, env.state_shape, env.state_dtype)
                    for _ in range(G)]
        self.slots: list[Optional[_Slot]] = [None] * G
        self.queue: list[SearchRequest] = []
        self.completed: list[SearchResult] = []
        self.stats = ServiceStats()
        self.last_decision: dict = {}   # per-superstep occupancy/compaction
        # fixed per-slot finalize width (vmapped finalize needs one shape)
        self.K = p * cfg.Fp if cfg.expand_all else p

    # ---- admission ----
    def submit(self, req: SearchRequest):
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        for g in range(self.G):
            if self.slots[g] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            res = SearchResult(uid=req.uid, submitted_at=req.submitted_at)
            s0 = self.env.initial_state(req.seed)
            na = self.env.num_actions(s0)
            if na == 0:  # degenerate: nothing to search
                res.terminal = True
                self._finish(res)
                continue
            self.exec.reset_slot(g, na)
            self.sts[g].flush(s0)
            self.slots[g] = _Slot(req=req, res=res, root_state=s0)
            self.stats.admitted += 1

    def _active(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], bool)

    # ---- occupancy decision: masked full arena vs gathered sub-arena ----
    def _pick_execution(self, active: np.ndarray):
        """Return (executor, exec_active, rows, act_idx): `rows[i]` is the
        arena row carrying active slot `act_idx[i]` on the chosen executor
        (identity when masked, dense prefix when compacted)."""
        act_idx = np.flatnonzero(active)
        A = len(act_idx)
        Gc = 1 << (A - 1).bit_length()     # pow2 pad: bounded program cache
        compacted = (self.compact_threshold > 0.0
                     and A <= self.compact_threshold * self.G
                     and Gc < self.G)
        self.last_decision = {
            "A": A, "G": self.G, "occupancy": A / self.G,
            "compacted": compacted, "G_exec": Gc if compacted else self.G,
        }
        if compacted:
            sub = self.exec.gather_sub(act_idx, Gc)
            return sub, np.arange(Gc) < A, np.arange(A), act_idx
        return self.exec, active, act_idx, act_idx

    # ---- one fused superstep over all occupied slots ----
    def superstep(self) -> bool:
        self._admit()
        active = self._active()
        if not active.any():
            return False
        p, cfg = self.p, self.cfg
        t0 = time.perf_counter()

        ex, ex_active, rows, act_idx = self._pick_execution(active)
        Ge = ex.G
        sel_dev = ex.selection(ex_active, p)
        sel = ex.sel_to_host(sel_dev)                         # [Ge, p, ...]
        new_nodes = ex.insert(ex_active, sel_dev)             # [Ge, p, Fp]
        t1 = time.perf_counter()

        # host expansion: every slot's pending expansions through the
        # engine (one flattened env batch in vector/pool mode), then ONE
        # fused Simulation batch
        hx = self.expander.expand(
            [(g, self.sts[g], {k: v[r] for k, v in sel.items()},
              new_nodes[r]) for r, g in zip(rows, act_idx)])
        t_x = time.perf_counter()
        self.stats.t_expand += t_x - t1
        fused = np.concatenate([hx[g].sim_states for g in act_idx])
        t2 = time.perf_counter()
        values, priors = self.sim.evaluate(fused)
        t3 = time.perf_counter()
        self.stats.sim_rows += len(fused)
        self.stats.sim_batches += 1
        self.stats.max_fused_rows = max(self.stats.max_fused_rows, len(fused))

        # split fused results, finalize + BackUp across all slots at once
        values_fx = np.asarray(fx.encode(np.asarray(values)), np.int32)
        fin_nodes = np.full((Ge, self.K), NULL, np.int32)
        fin_na = np.zeros((Ge, self.K), np.int32)
        fin_term = np.zeros((Ge, self.K), np.int32)
        fin_pp = np.full((Ge, p), NULL, np.int32)
        fin_pf = np.zeros((Ge, p, cfg.Fp), np.int32)
        sim_nodes = np.zeros((Ge, p), np.int32)
        vals = np.zeros((Ge, p), np.int32)
        for i, (r, g) in enumerate(zip(rows, act_idx)):
            row = slice(i * p, (i + 1) * p)
            pr = priors[row] if priors is not None else None
            (fin_nodes[r], fin_na[r], fin_term[r], fin_pp[r],
             fin_pf[r]) = hx[g].padded_finalize_args(self.K, p, cfg.Fp, pr)
            sim_nodes[r] = hx[g].sim_nodes
            vals[r] = values_fx[row]
        t4 = time.perf_counter()

        ex.finalize(fin_nodes, fin_na, fin_term, fin_pp, fin_pf)
        ex.backup(ex_active, sel_dev, sim_nodes, vals,
                  self.alternating_signs)
        if ex is not self.exec:
            self.exec.scatter_sub(ex, act_idx)
            self.stats.compacted_supersteps += 1
        t5 = time.perf_counter()

        self.stats.supersteps += 1
        self.stats.occupancy_sum += len(act_idx) / self.G
        self.stats.t_intree += (t1 - t0) + (t5 - t4)
        self.stats.t_host += (t2 - t1) + (t4 - t3)
        self.stats.t_sim += t3 - t2

        self._commit_moves(act_idx)
        return True

    # ---- move boundary: commit / advance / evict ----
    def _commit_moves(self, act_idx):
        sizes = self.exec.sizes()
        best = None  # lazy: only computed when some slot finished its move
        for g in act_idx:
            slot = self.slots[g]
            slot.move_supersteps += 1
            slot.res.supersteps += 1
            size = int(sizes[g])
            done_move = (
                slot.move_supersteps >= slot.req.budget
                or size >= self.cfg.X
                or size == slot.prev_size  # saturated: no node inserted
            )
            slot.prev_size = size
            if not done_move:
                continue
            if best is None:
                best = self.exec.best_actions()
            self._advance(g, int(best[g]))

    def _advance(self, g: int, a: int):
        slot, env = self.slots[g], self.env
        snap = self.exec.slot_snapshot(g)
        root = int(snap["root"])
        counts = np.array(snap["edge_N"][root][: self.cfg.F], np.int64)
        new_state, reward, term = env.step(slot.root_state, a)
        slot.res.actions.append(a)
        slot.res.rewards.append(float(reward))
        slot.res.visit_counts.append(counts)
        slot.moves_done += 1
        if term or slot.moves_done >= slot.req.moves:
            slot.res.terminal = bool(term)
            if slot.req.keep_tree:
                slot.res.tree_snapshot = snap
            self._finish(slot.res)
            self.slots[g] = None
            return
        # long-lived request: next move on the same slot
        slot.root_state = new_state
        slot.move_supersteps = 0
        new_root = int(snap["child"][root, a])
        if self.reuse_subtree and new_root != NULL:
            arrays, old2new = reroot.reroot(self.cfg, snap, new_root)
            self.exec.write_slot(g, arrays)
            self.sts[g].compact(old2new)
            slot.prev_size = int(arrays["size"])
        else:  # paper-faithful full flush
            self.exec.reset_slot(g, max(env.num_actions(new_state), 1))
            self.sts[g].flush(new_state)
            slot.prev_size = 1

    def _finish(self, res: SearchResult):
        res.done_at = time.perf_counter()
        self.completed.append(res)
        self.stats.completed += 1

    # ---- drive to completion ----
    def run(self, max_supersteps: int = 100_000) -> list[SearchResult]:
        while (self.queue or self._active().any()) \
                and self.stats.supersteps < max_supersteps:
            if not self.superstep():
                break
        return self.completed

    def close(self):
        """Release expansion-engine resources (process pool, if any)."""
        self.expander.close()
