"""SearchService — single-bucket compatibility wrapper over ArenaPool.

The service stack is three layers now (multi-arena frontend refactor):

  frontend.py   ServiceFrontend — accepts requests carrying their own
                TreeConfig, buckets them by shape class
                (core.tree.bucket_key: same X/D/semantics, fanout padded
                to a shared Fp lane width) into per-bucket arena pools,
                and round-robins supersteps across pools.
  pool.py       ArenaPool — one bucket's G-slot arena + StateTables +
                expansion engine + admission queue; the BSP superstep
                (Selection / Insertion / host expansion / fused
                Simulation / BackUp), move commit / reroot advance /
                eviction, and the occupancy decision with persistent
                CompactionSessions (core.executor) and hysteresis.
  this module   SearchService — ArenaPool under its historical name and
                signature: the one-config service every existing test,
                bench and example was written against.  It IS an
                ArenaPool (subclass adding nothing), so the scheduler
                surface — submit/superstep/run, stats, last_decision,
                exec — is unchanged.

Mirrors serving/batcher.py's slot pattern one level up the stack: the
pool is a TreeArena of G slots instead of a KV-cache pool, a request is a
whole search instead of a prompt, and the decode tick is a BSP superstep
advancing EVERY occupied slot together, with all slots' simulation states
fused into ONE SimulationBackend.evaluate batch (the cross-request
analogue of the within-tree worker batching the paper's Fig. 5 measures).
See pool.py for the lifecycle and compaction details.
"""

from __future__ import annotations

from repro.service.pool import (
    ArenaPool, SearchRequest, SearchResult, ServiceStats,
)

__all__ = ["ArenaPool", "SearchRequest", "SearchResult", "SearchService",
           "ServiceStats"]


class SearchService(ArenaPool):
    """G-slot multi-tree MCTS server for ONE TreeConfig (one host, one
    device program per phase) — the single-bucket special case of the
    frontend/pool stack.  Heterogeneous request configs need
    service.frontend.ServiceFrontend, which routes each request to the
    ArenaPool serving its bucket."""
