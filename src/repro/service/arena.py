"""Tree-arena executors — compat shim over the unified stack.

The two executor hierarchies this module and core.mcts used to carry
(single-tree vs arena) are collapsed into core.executor: one
InTreeExecutor protocol, every backend (reference / faithful / relaxed /
wavefront / pallas) driving G >= 1 stacked tree slots under an active
mask.  The arena-native [G]-grid Pallas kernels serve the arena directly
now — variant="pallas" is a first-class executor, no longer gated out.

The old service-layer names remain importable here; new code should use
repro.core.executor.
"""

from __future__ import annotations

from repro.core.executor import (
    InTreeExecutor,
    JaxExecutor as JaxArenaExecutor,
    PallasExecutor as PallasArenaExecutor,
    ReferenceExecutor as ReferenceArenaExecutor,
    make_intree_executor,
)
from repro.core.tree import TreeConfig

__all__ = [
    "InTreeExecutor", "JaxArenaExecutor", "PallasArenaExecutor",
    "ReferenceArenaExecutor", "make_arena_executor", "make_intree_executor",
]


def make_arena_executor(cfg: TreeConfig, G: int, name: str) -> InTreeExecutor:
    return make_intree_executor(cfg, G, name)
