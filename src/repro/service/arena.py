"""Deprecated tree-arena executor shim (use repro.core.executor).

The two executor hierarchies this module and core.mcts used to carry
(single-tree vs arena) were collapsed into core.executor in the unified
executor stack PR: one InTreeExecutor protocol, every backend (reference
/ faithful / relaxed / wavefront / pallas) driving G >= 1 stacked tree
slots under an active mask.  The serving surface moved on again since —
the public API is service.client.SearchClient.

The old service-layer names resolve lazily (PEP 562) with a one-time
DeprecationWarning, so legacy imports keep working without charging
every `import repro.service` a warning.
"""

from __future__ import annotations

import warnings

from repro.core import executor as _executor
from repro.core.tree import TreeConfig

__all__ = [
    "InTreeExecutor", "JaxArenaExecutor", "PallasArenaExecutor",
    "ReferenceArenaExecutor", "make_arena_executor", "make_intree_executor",
]

_ALIASES = {
    "InTreeExecutor": "InTreeExecutor",
    "JaxArenaExecutor": "JaxExecutor",
    "PallasArenaExecutor": "PallasExecutor",
    "ReferenceArenaExecutor": "ReferenceExecutor",
    "make_intree_executor": "make_intree_executor",
}

_warned = False


def _warn_once():
    global _warned
    if not _warned:
        _warned = True
        warnings.warn(
            "repro.service.arena is deprecated: import executors from "
            "repro.core.executor (and serve through "
            "repro.service.client.SearchClient)",
            DeprecationWarning, stacklevel=3)


def _make_arena_executor(cfg: TreeConfig, G: int, name: str):
    return _executor.make_intree_executor(cfg, G, name)


def __getattr__(name: str):
    if name == "make_arena_executor":
        _warn_once()
        return _make_arena_executor
    if name in _ALIASES:
        _warn_once()
        return getattr(_executor, _ALIASES[name])
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
