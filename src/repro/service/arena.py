"""Tree arena — G independent UCTrees driven as one device program.

The paper's accelerator serves p workers on ONE tree and its stated
scalability ceiling is in-tree occupancy.  The service layer scales on the
other axis: G *independent* searches (one per user request) stacked into a
single pytree (core.tree.init_arena), with every in-tree phase vmapped
across slots (core.intree.*_arena).  One superstep of the arena is one
Selection + Insertion + BackUp launch for ALL active slots — the device
sees a [G, ...] batch instead of G ragged launches, exactly the
array-of-trees layout of Ragan et al. (arXiv:2508.20140) applied to the
paper's UCT decomposition.

Two executors share the ArenaExecutor interface:

  JaxArenaExecutor       — stacked trees + vmapped jit ops ("faithful",
                           "relaxed", "wavefront" variants; the Pallas
                           kernels manage their own grids and are not
                           vmappable, so variant="pallas" is rejected);
  ReferenceArenaExecutor — one sequential numpy MutableTree per slot, the
                           correctness oracle and CPU baseline for
                           benchmarks/bench_service.py.

Idle-slot semantics: ops run on every slot (uniform program, no ragged
dispatch) and tree.where_trees discards updates to inactive slots, so a
parked tree is bit-frozen while its neighbours search.  Slot snapshots and
writes (admission, re-root) are host-side and off the hot superstep path.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import intree, ref_sequential as ref
from repro.core.mcts import _sel_to_host
from repro.core.tree import (
    NULL, TreeConfig, UCTree, arena_set_slot, arena_slot, init_arena,
    init_tree, to_jax,
)

import jax.numpy as jnp


class JaxArenaExecutor:
    """Vmapped jit in-tree operations over G stacked trees."""

    def __init__(self, cfg: TreeConfig, G: int, variant: str = "faithful"):
        if variant not in ("faithful", "relaxed", "wavefront"):
            raise NotImplementedError(
                f"arena variant {variant!r}: only the vmappable jit paths "
                "(faithful/relaxed/wavefront) run under the arena")
        self.cfg, self.G, self.variant = cfg, G, variant
        self.trees = init_arena(cfg, G)

    def reset_slot(self, g: int, root_num_actions: int):
        self.trees = arena_set_slot(
            self.trees, g, init_tree(self.cfg, root_num_actions))

    def selection(self, active: np.ndarray, p: int):
        self.trees, sel = intree.select_arena(
            self.cfg, self.trees, jnp.asarray(active), p, self.variant)
        return sel

    def insert(self, active: np.ndarray, sel):
        self.trees, new_nodes = intree.insert_arena(
            self.cfg, self.trees, jnp.asarray(active), sel)
        return np.asarray(jax.device_get(new_nodes))

    def finalize(self, nodes, num_actions, terminal, prior_parent, priors_fx):
        self.trees = intree.finalize_arena(
            self.trees, jnp.asarray(nodes), jnp.asarray(num_actions),
            jnp.asarray(terminal), jnp.asarray(prior_parent),
            jnp.asarray(priors_fx))

    def backup(self, active, sel, sim_nodes, values_fx, alternating: bool):
        self.trees = intree.backup_arena(
            self.cfg, self.trees, jnp.asarray(active), sel,
            jnp.asarray(sim_nodes), jnp.asarray(values_fx), alternating)
        jax.block_until_ready(self.trees.size)

    def sel_to_host(self, sel) -> dict:
        return _sel_to_host(sel)

    def best_actions(self) -> np.ndarray:
        return np.asarray(jax.device_get(
            intree.best_root_action_arena(self.trees)))

    def sizes(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.trees.size))

    def slot_snapshot(self, g: int) -> dict:
        one = jax.device_get(arena_slot(self.trees, g))
        return {k: np.asarray(v) for k, v in dataclasses.asdict(one).items()}

    def write_slot(self, g: int, arrays: dict):
        self.trees = arena_set_slot(
            self.trees, g, to_jax(UCTree(**arrays)))


class ReferenceArenaExecutor:
    """Sequential numpy oracle: one MutableTree per slot, looped on host.

    Same interface and same stacked [G, ...] host-array convention as the
    jit arena so the scheduler is executor-agnostic; inactive slots produce
    zero rows the driver never reads.
    """

    def __init__(self, cfg: TreeConfig, G: int):
        self.cfg, self.G = cfg, G
        self.trees = [ref.MutableTree.from_tree(init_tree(cfg, xp=np))
                      for _ in range(G)]

    def reset_slot(self, g: int, root_num_actions: int):
        self.trees[g] = ref.MutableTree.from_tree(
            init_tree(self.cfg, root_num_actions, xp=np))

    def selection(self, active: np.ndarray, p: int) -> dict:
        cfg = self.cfg
        out = {
            "path_nodes": np.full((self.G, p, cfg.D), NULL, np.int32),
            "path_actions": np.full((self.G, p, cfg.D), NULL, np.int32),
            "depths": np.zeros((self.G, p), np.int32),
            "leaves": np.zeros((self.G, p), np.int32),
            "expand_action": np.full((self.G, p), NULL, np.int32),
            "n_insert": np.zeros((self.G, p), np.int32),
            "insert_base": np.zeros((self.G, p), np.int32),
        }
        for g in np.flatnonzero(active):
            t = self.trees[g]
            sel = ref.selection_phase(cfg, t, p)
            ni = sel["n_insert"]
            sel["insert_base"] = t.size + np.cumsum(ni) - ni
            for k, v in sel.items():
                out[k][g] = v
        return out

    def insert(self, active: np.ndarray, sel: dict) -> np.ndarray:
        p = sel["leaves"].shape[1]
        new_nodes = np.full((self.G, p, self.cfg.Fp), NULL, np.int32)
        for g in np.flatnonzero(active):
            slot_sel = {k: v[g] for k, v in sel.items()}
            new_nodes[g] = ref.insert_phase(self.cfg, self.trees[g], slot_sel)
        return new_nodes

    def finalize(self, nodes, num_actions, terminal, prior_parent, priors_fx):
        for g in range(self.G):
            ref.finalize_expansion(
                self.trees[g], nodes[g], num_actions[g], terminal[g],
                prior_parent[g], priors_fx[g])

    def backup(self, active, sel, sim_nodes, values_fx, alternating: bool):
        for g in np.flatnonzero(active):
            slot_sel = {k: v[g] for k, v in sel.items()}
            ref.backup_phase(self.cfg, self.trees[g], slot_sel,
                             sim_nodes[g], values_fx[g], alternating)

    def sel_to_host(self, sel) -> dict:
        return sel

    def best_actions(self) -> np.ndarray:
        return np.array([ref.best_root_action(self.cfg, t)
                         for t in self.trees], np.int32)

    def sizes(self) -> np.ndarray:
        return np.array([t.size for t in self.trees], np.int32)

    def slot_snapshot(self, g: int) -> dict:
        return {k: np.asarray(v) for k, v in
                dataclasses.asdict(self.trees[g].to_tree()).items()}

    def write_slot(self, g: int, arrays: dict):
        self.trees[g] = ref.MutableTree.from_tree(UCTree(**arrays))


def make_arena_executor(cfg: TreeConfig, G: int, name: str):
    if name == "reference":
        return ReferenceArenaExecutor(cfg, G)
    return JaxArenaExecutor(cfg, G, name)
