"""SearchClient — opaque request handles over the global scheduler.

The public serving API.  The paper's CPU workers interact with the FPGA
accelerator through a narrow request/response interface and never touch
tree internals; this module gives the serving stack the same shape: a
caller submits a SearchRequest and gets back a SearchHandle — never a
pool, never an arena — and drives progress with poll()/run_until()
instead of draining a run() loop to completion.

  client = SearchClient(env, sim, G=8, p=8, policy="weighted-queue-depth")
  h = client.submit(SearchRequest(uid=0, seed=0, budget=8, moves=4,
                                  cfg=my_cfg),
                    priority=1, deadline_supersteps=64)
  for ev in h.moves():            # streamed per-move events, as each
      print(ev.action)            # reroot commits — no terminal drain
  result = h.result()             # the terminal SearchResult (same data)

Handles:
  done()    — has the request's SearchResult been emitted (completion,
              cancel, or deadline eviction)?
  result()  — the SearchResult; with wait=True (default) the client is
              polled until it exists.
  cancel()  — evict the request now (queued or mid-flight); the partial
              result keeps any committed moves.  False once completed.
  moves()   — generator of MoveEvents in commit order, bit-identical to
              the terminal result's action/visit-distribution trace; it
              polls the scheduler lazily while the request is live, so
              iterating IS serving.

The client itself is a thin veneer: routing, policies, cross-bucket
admission, deadline eviction, cold-pool retirement and the cross-pool
fused Simulation batch all live in scheduler_core.SchedulerCore; the
superstep body lives in pool.ArenaPool.  ServiceFrontend and
SearchService remain as compatibility adapters over this stack.
"""

from __future__ import annotations

from typing import Callable, Iterator, Optional, Union

from repro.core.mcts import Environment, SimulationBackend
from repro.core.tree import TreeConfig
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.service.pool import MoveEvent, SearchRequest, SearchResult
from repro.service.scheduler_core import SchedulePolicy, SchedulerCore

__all__ = ["SearchClient", "SearchHandle"]


class SearchHandle:
    """Opaque handle to one submitted search.  Everything a caller may do
    with an in-flight request goes through here — tree slots, arenas and
    pools stay scheduler-internal."""

    def __init__(self, client: "SearchClient", uid: int, key: tuple):
        self._client = client
        self.uid = uid
        self._key = key          # bucket key (routing detail; not API)

    def __repr__(self):
        return f"SearchHandle(uid={self.uid}, status={self.status()!r})"

    # ---- state ----
    def done(self) -> bool:
        """True once the terminal SearchResult exists — by completion,
        cancel() or deadline eviction — even if the result has since
        been dropped by the retired-pool result TTL (status "expired")."""
        core = self._client.core
        return self.uid in core.results or self.uid in core.expired_uids

    def status(self) -> str:
        """'queued' | 'active' | 'done' | 'cancelled' | 'evicted' |
        'expired' (result dropped by the retired-pool TTL)."""
        res = self._client.core.results.get(self.uid)
        if res is not None:
            if res.deadline_evicted:
                return "evicted"
            if res.cancelled:
                return "cancelled"
            return "done"
        if self.uid in self._client.core.expired_uids:
            return "expired"
        pool = self._client.core.pools.get(self._key)
        # holds() is retired-safe: a retired pool's slot list is released
        # with its arena, so probing pool.slots directly here would read
        # freed state on a pool awaiting resurrection
        if pool is not None and pool.holds(self.uid):
            return "active"
        return "queued"

    # ---- terminal result ----
    def result(self, wait: bool = True,
               max_ticks: int = 100_000) -> SearchResult:
        """The request's SearchResult.  With wait=True the client is
        polled until the result exists; raises RuntimeError if the
        scheduler drains without producing it (never happens for a
        submitted uid unless max_ticks is exhausted).  `max_ticks`
        bounds the CLOCK, not poll() calls: one fused dispatch advances
        `core.ticks` by up to K per call, so counting calls would let a
        fused run burn K times the stated budget."""
        core = self._client.core
        start = core.ticks
        while (wait and self.uid not in core.results
               and core.ticks - start < max_ticks):
            if not self._client.poll(1):
                break
        if wait and self.uid not in core.results:
            # clock budget exhausted (or drained) with an overlap gang
            # possibly still in flight: finish it without advancing the
            # clock — its commits may be exactly this request's result
            core.drain_inflight()
        res = core.results.get(self.uid)
        if res is None:
            if self.uid in core.expired_uids:
                raise RuntimeError(
                    f"request uid={self.uid} result expired: it outlived "
                    f"result_ttl_ticks={core.result_ttl_ticks} on a "
                    f"retired pool and was dropped")
            raise RuntimeError(
                f"request uid={self.uid} has no result yet "
                f"(status={self.status()!r}); poll() the client or call "
                f"result(wait=True)")
        return res

    def cancel(self) -> bool:
        """Evict the request now.  The emitted result keeps any committed
        moves and is flagged cancelled; False once already completed."""
        return self._client.core.cancel(self.uid, self._key)

    # ---- streaming ----
    def moves(self) -> Iterator[MoveEvent]:
        """Yield MoveEvents in commit order, polling the scheduler lazily
        while the request is live — the streamed trace is bit-identical
        to the terminal result's actions/visit_counts (pinned in
        tests/test_client.py).  Iteration ends when the request's last
        move commits, or early when it is cancelled/evicted or the
        scheduler drains."""
        core = self._client.core
        emitted = 0
        live = True
        log = None
        while live:
            # a final flush still runs after done()/drain ends the loop
            live = not self.done() and self._client.poll(1) > 0
            # hold the FIRST list object resolved for this uid: the pool
            # listener appends to it in place, while the retired-pool
            # result TTL may pop the dict entry mid-iteration — re-fetching
            # would then silently truncate the tail of the stream
            if log is None:
                log = core.move_log.get(self.uid)
            cur = () if log is None else log
            while emitted < len(cur):
                yield cur[emitted]
                emitted += 1


class SearchClient:
    """Submit searches, get handles, drive progress — the one public
    entry point of the serving stack.

    Construction mirrors the historical frontends (env + sim + G slots x
    p workers per bucket, executor/compaction/expansion knobs) and adds
    the scheduler levers: `policy` (round-robin | weighted-queue-depth |
    deadline-aware, or a SchedulePolicy instance), `fuse_across_pools`
    (one evaluate() batch spanning every advancing pool on gang ticks;
    default: whenever the policy gangs), and `retire_after_ticks` (cold
    pools release their arena after this many idle global ticks and are
    resurrected on demand).

    Observability: `trace=True` (or a Tracer instance) records phase and
    request-lifecycle spans, exported with `trace_export()` as
    Chrome-trace JSON for ui.perfetto.dev; `metrics=True` (or a
    MetricsRegistry) collects scheduler/pool telemetry rendered by
    `metrics()` in Prometheus exposition format.  `result_ttl_ticks`
    drops completed results of retired pools after that many global
    ticks (their handles report status "expired").  All three are off by
    default; traced runs are bit-identical to untraced ones
    (tests/test_executor_matrix.py).

    Multi-device serving: `n_shards=D` partitions every bucket's G slots
    into D per-device shard arenas (G must be a multiple of D); each
    admission lands on the least-loaded shard and runs device-locally,
    while results stay bit-identical to n_shards=1 for every request.
    `shard_devices` pins the shard→device map (default:
    launch.mesh.serving_devices, round-robin over jax.devices()).

    Overlap serving: `overlap=True` pipelines each pool's supersteps over
    `n_gangs` double-buffered slot gangs — one gang's host expansion/
    simulation runs while another's device phases are already dispatched
    (service.pool, "Overlap mode").  Per-request results are unchanged;
    clock-budget exits (result/run_until/drain) finish any in-flight gang
    without advancing the clock past the budget.  Incompatible with
    `compact_threshold > 0`.
    """

    def __init__(
        self,
        env: Environment,
        sim: Optional[SimulationBackend] = None,
        G: int = 4,
        p: int = 8,
        executor: str = "faithful",
        default_cfg: Optional[TreeConfig] = None,
        policy: Union[str, SchedulePolicy] = "round-robin",
        fuse_across_pools: Optional[bool] = None,
        retire_after_ticks: Optional[int] = None,
        alternating_signs: bool = False,
        reuse_subtree: bool = True,
        compact_threshold: float = 0.0,
        compact_exit_threshold: Optional[float] = None,
        persistent_compaction: bool = True,
        expansion: str = "loop",
        pool_workers: int = 2,
        supersteps_per_dispatch: int = 1,
        trace: Union[bool, Tracer] = False,
        metrics: Union[bool, MetricsRegistry] = False,
        trace_capacity: int = 1 << 16,
        result_ttl_ticks: Optional[int] = None,
        n_shards: int = 1,
        shard_devices: Optional[list] = None,
        overlap: bool = False,
        n_gangs: int = 2,
        sim_backend: Optional[SimulationBackend] = None,
    ):
        # `sim_backend` is the serving-subsystem spelling (repro.sim
        # SimServer / CachedSimBackend / LMContinuationBackend); `sim`
        # the historical positional.  One of them, never both.
        if sim_backend is not None:
            if sim is not None:
                raise ValueError(
                    "pass the simulation backend as `sim` OR "
                    "`sim_backend`, not both")
            sim = sim_backend
        if sim is None:
            raise ValueError("SearchClient needs a simulation backend: "
                             "pass `sim` or `sim_backend`")
        self.tracer: Optional[Tracer] = (
            trace if isinstance(trace, Tracer)
            else Tracer(capacity=trace_capacity) if trace else None)
        self.registry: Optional[MetricsRegistry] = (
            metrics if isinstance(metrics, MetricsRegistry)
            else MetricsRegistry() if metrics else None)
        # serving backends carry their own telemetry (sim_server_*,
        # sim_cache_*, serving_*): rebind it onto this client's registry
        # so metrics() renders one coherent snapshot
        if self.registry is not None and hasattr(sim, "bind_metrics"):
            sim.bind_metrics(self.registry)
        self.core = SchedulerCore(
            env, sim, G, p, executor=executor, default_cfg=default_cfg,
            policy=policy, fuse_across_pools=fuse_across_pools,
            retire_after_ticks=retire_after_ticks,
            alternating_signs=alternating_signs,
            reuse_subtree=reuse_subtree,
            compact_threshold=compact_threshold,
            compact_exit_threshold=compact_exit_threshold,
            persistent_compaction=persistent_compaction,
            expansion=expansion, pool_workers=pool_workers,
            supersteps_per_dispatch=supersteps_per_dispatch,
            tracer=self.tracer, metrics=self.registry,
            result_ttl_ticks=result_ttl_ticks,
            n_shards=n_shards, shard_devices=shard_devices,
            overlap=overlap, n_gangs=n_gangs)
        self._handles: dict[int, SearchHandle] = {}

    # ---- submission ----
    def submit(self, req: SearchRequest, priority: Optional[int] = None,
               deadline_supersteps: Optional[int] = None) -> SearchHandle:
        """Queue a search and return its handle.  `priority` and
        `deadline_supersteps` override the request's own fields when
        given (higher priority admits first; the deadline is a global-
        tick budget after which the scheduler evicts the request with
        whatever moves it committed)."""
        if priority is not None:
            req.priority = int(priority)
        if deadline_supersteps is not None:
            req.deadline_supersteps = int(deadline_supersteps)
        _, key = self.core.submit(req)
        handle = SearchHandle(self, req.uid, key)
        self._handles[req.uid] = handle
        return handle

    def handle(self, uid: int) -> SearchHandle:
        return self._handles[uid]

    # ---- progress ----
    def poll(self, budget: int = 1) -> int:
        """Advance up to `budget` scheduler ticks; returns how many did
        work (0 = fully drained).  The non-blocking replacement for the
        old drain-only run()."""
        n = 0
        for _ in range(max(0, int(budget))):
            if not self.core.tick():
                break
            n += 1
        return n

    def run_until(self, pred: Callable[["SearchClient"], bool],
                  max_ticks: int = 100_000) -> bool:
        """Tick until `pred(client)` holds (True) or the scheduler drains
        / max_ticks pass without it (returns pred's final value).  Like
        result(), the bound is against the clock — fused dispatches
        advance it by up to K per tick() call."""
        start = self.core.ticks
        while not pred(self):
            if (self.core.ticks - start >= max_ticks
                    or not self.core.tick()):
                # budget/drain exit: complete any in-flight overlap gang
                # (no clock advance) before the final predicate check
                self.core.drain_inflight()
                return bool(pred(self))
        return True

    def drain(self, max_ticks: int = 100_000) -> list[SearchResult]:
        """Run every queued/in-flight request to its terminal result and
        return them all (submission-bucket order) — the compatibility
        path the frontend adapters drain through."""
        return self.core.run(max_ticks)

    # ---- views ----
    @property
    def stats(self):
        return self.core.stats

    def pool_summaries(self) -> list[dict]:
        return self.core.pool_summaries()

    # ---- observability ----
    def metrics(self) -> str:
        """One Prometheus-exposition-format snapshot of every metric, or
        "" when the client was built without `metrics=True`."""
        return "" if self.registry is None else self.registry.render()

    def trace_export(self, path=None) -> dict:
        """The recorded trace as Chrome-trace JSON (open at
        https://ui.perfetto.dev); with `path` the JSON is also written
        there.  Requires `trace=True` (or a Tracer) at construction."""
        if self.tracer is None:
            raise RuntimeError(
                "tracing is off: build the client with trace=True (or "
                "pass a repro.obs.Tracer) to record spans")
        return self.tracer.export(path)

    def close(self):
        self.core.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
