"""SchedulerCore — global cross-pool scheduling behind the SearchClient.

Mirsoleimani et al.'s *Structured Parallel Programming for MCTS* argues
the scheduler, not the tree ops, should own parallel structure; the
paper's own CPU workers talk to the accelerator through a narrow
request/response interface and never see tree internals.  This module is
that split made literal for the serving layer: ArenaPool owns one shape
class's BSP superstep body, and everything that spans buckets lives here

  * routing      — requests are bucketed by shape class
                   (core.tree.bucket_key: exact X/D/semantics, fanout
                   padded to the shared Fp lane width) into lazily
                   created ArenaPools, all sharing ONE host-expansion
                   engine;
  * admission    — a pluggable SchedulePolicy decides which pools advance
                   each global tick and how many slots each bucket may
                   fill (per-bucket G sizing from queue depth — the
                   cross-bucket fairness lever the ROADMAP named);
  * simulation   — sim-state shapes are env-, not config-, dependent, so
                   a gang tick concatenates every advancing pool's
                   pending rows into ONE SimulationBackend.evaluate call
                   and splits the results back per pool (the cross-pool
                   fusion that used to stop at pool boundaries);
  * deadlines    — requests carrying deadline_supersteps are evicted (via
                   ArenaPool.cancel) at the first tick past their budget,
                   keeping whatever moves they committed;
  * retirement   — a pool idle for `retire_after_ticks` global ticks
                   closes its CompactionSession and releases its arena
                   (executor.release()); the next submit to its bucket
                   resurrects it.  Bounds arena memory under config churn.

Policies:

  round-robin          — one pool per tick, rotating: bit-identical to
                         the historical ServiceFrontend loop (the
                         compatibility default).
  weighted-queue-depth — a gang tick: every pool with work advances,
                         deepest queue first, with per-bucket admission
                         caps proportional to queue-depth share; the
                         cross-pool fused evaluate batch comes from here.
  deadline-aware       — the pool holding the most urgent deadline
                         advances first each tick, and its admission
                         order prefers earlier deadlines within a
                         priority class.

Scheduling never changes what a request computes — per-slot tree
evolution is schedule-independent (tests/test_executor_matrix.py), so
every policy, fused or not, returns bit-identical per-request results;
policies only move WHEN work happens (fairness, deadlines, batch shape).

Multi-device serving: with ``n_shards=D`` every pool partitions its G
slots into D per-device shard arenas (core/sharded.py) and the POOL does
cross-device placement — each admission goes to the least-loaded enabled
shard (ArenaPool._place_slot; ties break to the lowest shard id, then
lowest free slot, so D=1 reduces exactly to the historical order).  The
core stays device-agnostic: cross-pool fused evaluate batching, the
policies, deadlines and retirement all operate on whole pools, and the
global clock still advances by the deepest fused dispatch — now the max
over per-shard device dispatches.  Placement is scheduling, not
semantics: per-request results are bit-identical at any D.
"""

from __future__ import annotations

import math
import time
from typing import Optional, Union

import numpy as np

from repro.core.expand import ExpansionEngine
from repro.core.mcts import Environment, SimulationBackend
from repro.envs.device import has_async_sim
from repro.core.tree import TreeConfig, bucket_key, canonical_config
from repro.obs.metrics import NULL_REGISTRY
from repro.obs.trace import NULL_TRACER
from repro.service.pool import (
    ArenaPool, MoveEvent, SearchRequest, SearchResult, ServiceStats,
    bucket_label,
)

__all__ = [
    "POLICY_NAMES", "SchedulePolicy", "RoundRobinPolicy",
    "WeightedQueueDepthPolicy", "DeadlineAwarePolicy", "SchedulerCore",
    "make_policy",
]


def _depth(pool: ArenaPool) -> int:
    """A pool's backlog: queued plus in-flight requests."""
    return len(pool.queue) + pool.load()


class SchedulePolicy:
    """Which pools advance on a tick, in what order, and how many slots
    each may fill.  Stateless except where noted; one instance serves one
    SchedulerCore (round-robin keeps a cursor)."""

    name = "base"
    #: gang=False advances the FIRST pool in `order` that yields work
    #: (one superstep per tick — the historical frontend cadence);
    #: gang=True advances EVERY pool with work in one tick, which is what
    #: the cross-pool fused evaluate batches over.
    gang = False
    #: pools admit earliest-deadline-first within a priority class
    deadline_first = False

    def order(self, core: "SchedulerCore") -> list:
        """Bucket keys in the order the core should try them this tick."""
        return list(core._order)

    def admit_limits(self, core: "SchedulerCore") -> dict:
        """Per-bucket active-slot caps ({} = every pool may fill to G)."""
        return {}

    def advanced(self, core: "SchedulerCore", key) -> None:
        """Notification that `key`'s pool advanced this tick."""


class RoundRobinPolicy(SchedulePolicy):
    """One pool per tick, rotating — today's ServiceFrontend behavior."""

    name = "round-robin"

    def __init__(self):
        self._rr = 0

    def order(self, core):
        n = len(core._order)
        return [core._order[(self._rr + i) % n] for i in range(n)]

    def advanced(self, core, key):
        self._rr = (core._order.index(key) + 1) % len(core._order)


class WeightedQueueDepthPolicy(SchedulePolicy):
    """Gang tick, deepest backlog first, admission caps proportional to
    queue-depth share (per-bucket G sizing: a bucket with 80% of the
    backlog may fill 80% of its slots; every bucket keeps at least 1).

    The share is computed on EWMA-smoothed depths, not instantaneous
    ones: a one-tick burst into one bucket no longer slams every other
    bucket's cap to 1 and back (the carried-forward ROADMAP limit).
    ``ewma_alpha`` is the usual smoothing weight on the newest sample —
    1.0 recovers the unsmoothed behavior.  A bucket's EWMA is seeded
    with its first observed depth, so the first tick a bucket has work
    behaves exactly as before smoothing existed.  The smoothed load is
    exported per bucket as the `service_smoothed_load` gauge.

    ``fairness_floor`` hardens the "every bucket keeps at least 1"
    guarantee into at least one ADMISSION: a share-of-backlog cap of 1
    is satisfied by a bucket's single long-running active request, so
    its queued requests could starve behind a bucket that dominates the
    depth share.  With the floor on, any bucket with queued work gets a
    cap of at least ``min(G, load + 1)`` — room for one fresh admission
    per gang tick, regardless of share."""

    name = "weighted-queue-depth"
    gang = True

    def __init__(self, ewma_alpha: float = 0.5,
                 fairness_floor: bool = True):
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(
                f"ewma_alpha must be in (0, 1]: {ewma_alpha}")
        self.ewma_alpha = ewma_alpha
        self.fairness_floor = fairness_floor
        self._ewma: dict = {}
        self._last_tick = None

    def order(self, core):
        keys = [k for k in core._order if core.pools[k].has_work()]
        return sorted(
            keys, key=lambda k: (-_depth(core.pools[k]),
                                 core._order.index(k)))

    def _smoothed_depths(self, core) -> dict:
        """EWMA over each with-work bucket's backlog, advanced at most
        once per core tick (admit_limits may be probed more often).

        Entries for buckets with no work — drained or retired — are
        PRUNED, not kept: a retired bucket that resurrects later must
        reseed its EWMA from its fresh backlog, or the stale smoothed
        depth from its previous life would skew every bucket's
        admission share for ticks after resurrection."""
        depths = {k: _depth(core.pools[k]) for k in core._order
                  if core.pools[k].has_work()}
        if core.ticks != self._last_tick:
            self._last_tick = core.ticks
            a = self.ewma_alpha
            reg = getattr(core, "registry", NULL_REGISTRY)
            for k in [k for k in self._ewma if k not in depths]:
                del self._ewma[k]
            for k, d in depths.items():
                prev = self._ewma.get(k)
                self._ewma[k] = d if prev is None else a * d + (1 - a) * prev
                reg.gauge(
                    "service_smoothed_load",
                    "EWMA-smoothed backlog (queued + in-flight) per bucket",
                    bucket=bucket_label(core.pools[k].cfg),
                ).set(round(self._ewma[k], 4))
        return {k: self._ewma[k] for k in depths}

    def admit_limits(self, core):
        depths = self._smoothed_depths(core)
        total = sum(depths.values())
        if total == 0:
            return {}
        caps = {k: max(1, min(core.pools[k].G,
                              math.ceil(core.pools[k].G * d / total)))
                for k, d in depths.items()}
        if self.fairness_floor:
            for k in caps:
                pool = core.pools[k]
                if pool.queue:
                    caps[k] = max(caps[k], min(pool.G, pool.load() + 1))
        return caps


class DeadlineAwarePolicy(SchedulePolicy):
    """The pool holding the most urgent deadline advances first; its
    admission prefers earlier deadlines within a priority class.  Pools
    with no deadlines fall back to backlog order."""

    name = "deadline-aware"
    deadline_first = True

    def _slack(self, core, key) -> float:
        # deadline_ticks() is retired-safe: a retired pool's slot list
        # is released with its arena, so probing pool.slots here would
        # read freed state (queued deadlines still count — queued work
        # on a retired pool is what triggers resurrection)
        deadlines = core.pools[key].deadline_ticks()
        return (min(deadlines) - core.ticks) if deadlines else math.inf

    def order(self, core):
        keys = [k for k in core._order if core.pools[k].has_work()]
        return sorted(
            keys, key=lambda k: (self._slack(core, k),
                                 -_depth(core.pools[k]),
                                 core._order.index(k)))


POLICY_NAMES = ("round-robin", "weighted-queue-depth", "deadline-aware")

_POLICIES = {
    "round-robin": RoundRobinPolicy,
    "weighted-queue-depth": WeightedQueueDepthPolicy,
    "deadline-aware": DeadlineAwarePolicy,
}


def make_policy(policy: Union[str, SchedulePolicy]) -> SchedulePolicy:
    if isinstance(policy, SchedulePolicy):
        return policy
    if policy not in _POLICIES:
        raise ValueError(f"unknown schedule policy {policy!r}: one of "
                         f"{POLICY_NAMES} (or a SchedulePolicy instance)")
    return _POLICIES[policy]()


class SchedulerCore:
    """Config-bucketed arena pools under one global tick clock.

    The engine room of SearchClient (and, through it, the ServiceFrontend
    / SearchService compatibility adapters).  Owns the pools dict, the
    policy, the deadline ledger, cold-pool retirement, and the cross-pool
    fused Simulation batch.
    """

    def __init__(
        self,
        env: Environment,
        sim: SimulationBackend,
        G: int,
        p: int,
        executor: str = "faithful",
        default_cfg: Optional[TreeConfig] = None,
        policy: Union[str, SchedulePolicy] = "round-robin",
        fuse_across_pools: Optional[bool] = None,
        retire_after_ticks: Optional[int] = None,
        alternating_signs: bool = False,
        reuse_subtree: bool = True,
        compact_threshold: float = 0.0,
        compact_exit_threshold: Optional[float] = None,
        persistent_compaction: bool = True,
        expansion: str = "loop",
        pool_workers: int = 2,
        supersteps_per_dispatch: int = 1,
        tracer=None,
        metrics=None,
        result_ttl_ticks: Optional[int] = None,
        n_shards: int = 1,
        shard_devices: Optional[list] = None,
        overlap: bool = False,
        n_gangs: int = 2,
    ):
        self.env, self.sim = env, sim
        self.G, self.p = G, p
        self.executor = executor
        self.default_cfg = default_cfg
        self.policy = make_policy(policy)
        # observability: the scheduler claims trace track 0; each pool
        # gets its own track as it is created (pool.py).  No-op defaults.
        self.trace = NULL_TRACER if tracer is None else tracer
        self.registry = NULL_REGISTRY if metrics is None else metrics
        self._track = self.trace.track("scheduler")
        self._m_ticks = self.registry.counter(
            "service_ticks_total", "global scheduler ticks")
        self._m_xpool = self.registry.counter(
            "service_xpool_batches_total",
            "fused evaluate() calls spanning >1 pool")
        self._m_fused_rows = self.registry.histogram(
            "service_fused_batch_rows",
            "rows per cross-pool fused simulation batch")
        self._m_expired = self.registry.counter(
            "service_expired_results_total",
            "retired-pool results dropped by the result TTL")
        # results of retired pools older than this many ticks are dropped
        # (handles report status "expired"); None keeps them forever
        self.result_ttl_ticks = result_ttl_ticks
        self.expired_uids: set[int] = set()
        # fuse the gang tick's Simulation rows across pools into ONE
        # evaluate() call; None = whenever the policy gangs.  False keeps
        # gang ticks but evaluates per pool (the bit-identity control).
        self.fuse = self.policy.gang if fuse_across_pools is None \
            else fuse_across_pools
        self.retire_after_ticks = retire_after_ticks
        # fused K-superstep device dispatch (repro.core.fused): pools
        # whose env/sim carry device twins run up to K supersteps per
        # tick in ONE compiled program; host-bound pools keep the
        # phase-by-phase cadence on the same clock
        self.supersteps_per_dispatch = max(1, int(supersteps_per_dispatch))
        # D-sharded serving: every bucket's pool partitions its G slots
        # across n_shards per-device arenas (core/sharded.py); the pool
        # owns intra-bucket cross-device placement, the core stays
        # device-agnostic
        self.n_shards = max(1, int(n_shards))
        self.shard_devices = shard_devices
        # overlap serving: every pool pipelines its supersteps over
        # n_gangs double-buffered gangs (service.pool, "Overlap mode");
        # tick()/begin_superstep stay call-compatible, and drain_inflight
        # completes in-flight gangs when a clock budget stops the loop
        self.overlap = bool(overlap)
        self.n_gangs = max(1, int(n_gangs))
        self._pool_kw = dict(
            alternating_signs=alternating_signs,
            reuse_subtree=reuse_subtree,
            compact_threshold=compact_threshold,
            compact_exit_threshold=compact_exit_threshold,
            persistent_compaction=persistent_compaction,
            supersteps_per_dispatch=supersteps_per_dispatch,
            n_shards=self.n_shards,
            shard_devices=shard_devices,
            overlap=self.overlap,
            n_gangs=self.n_gangs,
        )
        # ONE host-expansion engine (and process pool, in "pool" mode)
        # shared by every bucket.  pool_workers sizes that process pool —
        # latency-bound envs (RPC/simulator-call transitions) want more
        # workers than cores, CPU-bound envs want ~core count
        self.expander = ExpansionEngine(env, expansion,
                                        pool_workers=pool_workers,
                                        tracer=tracer, metrics=metrics)
        self.pools: dict = {}
        self._order: list = []          # bucket keys in creation order
        self.last_key = None            # bucket of the latest superstep
        self.ticks = 0                  # monotonic global tick clock
        # handle surface: per-request results and streamed move events,
        # fed by the pool listeners (non-draining — readable mid-flight)
        self.results: dict[int, SearchResult] = {}
        self.move_log: dict[int, list[MoveEvent]] = {}
        self._seen_uids: set[int] = set()   # O(1) duplicate-submit guard
        self._deadlines: list[tuple[int, int, tuple]] = []  # (tick, uid, key)
        # cross-pool fusion counters (BENCH service_xpool_fuse_* rows)
        self.xpool_batches = 0          # fused evaluate() calls spanning >1 pool
        self.xpool_rows_max = 0         # largest fused cross-pool batch
        self.xpool_pool_rows_max = 0    # largest single-pool share inside one

    # ---- routing ----
    def _pool_for(self, cfg: TreeConfig) -> ArenaPool:
        key = bucket_key(cfg)
        pool = self.pools.get(key)
        if pool is None:
            pool = ArenaPool(
                canonical_config(cfg), self.env, self.sim, self.G, self.p,
                executor=self.executor, expander=self.expander,
                tracer=self.trace, metrics=self.registry,
                **self._pool_kw)
            pool.clock = lambda: self.ticks
            pool.move_listener = self._on_move
            pool.result_listener = self._on_result
            self.pools[key] = pool
            self._order.append(key)
        return pool

    def submit(self, req: SearchRequest) -> tuple:
        """Route a request to its bucket's pool (created or resurrected on
        demand); returns (pool, bucket_key)."""
        cfg = req.cfg if req.cfg is not None else self.default_cfg
        if cfg is None:
            raise ValueError(
                f"request uid={req.uid} carries no TreeConfig and the "
                f"scheduler has no default_cfg")
        if req.cfg is None:
            req.cfg = cfg
        if req.uid in self._seen_uids:
            raise ValueError(f"request uid={req.uid} already submitted — "
                             f"uids are the handle identity and must be "
                             f"unique per client")
        self._seen_uids.add(req.uid)
        key = bucket_key(cfg)
        pool = self._pool_for(cfg)
        req.submit_tick = self.ticks
        if req.deadline_supersteps is not None:
            req.deadline_tick = self.ticks + int(req.deadline_supersteps)
            self._deadlines.append((req.deadline_tick, req.uid, key))
        pool.submit(req)
        pool.idle_ticks = 0
        return pool, key

    # ---- listener plumbing (the handle surface) ----
    def _on_move(self, ev: MoveEvent):
        self.move_log.setdefault(ev.uid, []).append(ev)

    def _on_result(self, res: SearchResult):
        self.results[res.uid] = res

    def cancel(self, uid: int, key=None, reason: str = "cancel") -> bool:
        """Evict a queued or in-flight request; False once it completed
        (results are immutable after eviction)."""
        if uid in self.results:
            return False
        pools = [self.pools[key]] if key in self.pools else \
            list(self.pools.values())
        return any(pool.cancel(uid, reason) for pool in pools)

    def _expire_deadlines(self):
        if not self._deadlines:
            return
        due = [d for d in self._deadlines if d[0] <= self.ticks]
        if not due:
            return
        self._deadlines = [d for d in self._deadlines if d[0] > self.ticks]
        for _, uid, key in due:
            self.cancel(uid, key, reason="deadline")

    def _fused_cap(self) -> Optional[int]:
        """Superstep cap for fused dispatches this tick: never run past
        the most urgent outstanding deadline, so deadline eviction keeps
        its per-tick granularity (the clock advances by the largest
        fused run, and the cap guarantees that advance stops at the
        nearest deadline).  None = no deadline pending, run the full K."""
        if not self._deadlines:
            return None
        return max(1, min(t for t, _, _ in self._deadlines) - self.ticks)

    # ---- the global tick ----
    def tick(self) -> bool:
        """One scheduler tick: expire deadlines, apply the policy's
        admission caps, advance the policy's pool choice (one pool, or a
        fused gang), then sweep idle pools toward retirement.  False when
        no pool had work."""
        self.ticks += 1
        self._m_ticks.inc()
        tok = self.trace.begin("tick", cat="sched", tid=self._track,
                               tick=self.ticks)
        self._expire_deadlines()
        limits = self.policy.admit_limits(self)
        for key, pool in self.pools.items():
            pool.admit_limit = limits.get(key)
            pool.deadline_first = self.policy.deadline_first
        pending = []
        fused_ns = []            # supersteps each fused pool ran this tick
        advanced_ids: set = set()
        cap = self._fused_cap()
        for key in self.policy.order(self):
            pool = self.pools[key]
            if pool.retired or not pool.has_work():
                continue
            if self.supersteps_per_dispatch > 1 and pool.fused_capable():
                # fused K-superstep device dispatch: admission,
                # simulation and move commits all happen inside; the
                # deadline cap keeps eviction granularity intact
                n = pool.fused_dispatch(max_supersteps=cap)
                if n == 0:
                    continue
                fused_ns.append(n)
                advanced_ids.add(id(pool))
            else:
                pend = pool.begin_superstep()
                if pend is None:
                    continue
                pending.append((pool, pend))
                advanced_ids.add(id(pool))
            self.last_key = key
            self.policy.advanced(self, key)
            if not self.policy.gang:
                break
        if pending:
            self._evaluate_and_finish(pending)
        if fused_ns:
            # the global clock counts supersteps of service time: a tick
            # whose deepest fused dispatch ran n supersteps advances the
            # clock by n (the +1 at tick entry already paid the first)
            self.ticks += max(fused_ns) - 1
        self._sweep_retirement(advanced=advanced_ids)
        if tok is not None:
            self.trace.end(tok)
        return bool(pending) or bool(fused_ns)

    def _evaluate_and_finish(self, pending):
        """ONE SimulationBackend.evaluate spanning every advancing pool
        (sim-state shapes are config-independent), results scattered back
        per pool — or per-pool evaluate when fusion is off / trivial."""
        if self.fuse and len(pending) > 1:
            rows = [len(pend.sim_states) for _, pend in pending]
            fused = np.concatenate(
                [pend.sim_states for _, pend in pending])
            t0 = time.perf_counter()
            with self.trace.span("simulate", cat="phase", tid=self._track,
                                 rows=len(fused), pools=len(pending)):
                values, priors = self.sim.evaluate(fused)
            t_sim = time.perf_counter() - t0
            self._m_xpool.inc()
            self._m_fused_rows.observe(len(fused))
            self.xpool_batches += 1
            self.xpool_rows_max = max(self.xpool_rows_max, len(fused))
            self.xpool_pool_rows_max = max(self.xpool_pool_rows_max,
                                           max(rows))
            off = 0
            for (pool, pend), r in zip(pending, rows):
                pr = None if priors is None else priors[off:off + r]
                pool.finish_superstep(
                    pend, values[off:off + r], pr,
                    t_sim=t_sim * r / max(len(fused), 1), own_batch=False)
                off += r
        elif has_async_sim(self.sim) and len(pending) > 1:
            # microbatching backend, fusion off: submit EVERY pool's rows
            # first, then collect — the server's admission window packs
            # rows from different pools into shared fixed-shape
            # microbatches (and dispatch-capable backends already have
            # device programs in flight while later submits assemble).
            # Per-row results are batch-composition independent
            # (sim.server padding), so this is bit-identical to the
            # per-pool evaluate loop below.
            tickets = [(pool, pend, self.sim.submit(pend.sim_states))
                       for pool, pend in pending]
            for pool, pend, ticket in tickets:
                t0 = time.perf_counter()
                with pool.trace.span("simulate", cat="phase",
                                     tid=pool._track,
                                     rows=len(pend.sim_states)):
                    values, priors = self.sim.collect(ticket)
                t_sim = time.perf_counter() - t0
                pool.finish_superstep(pend, values, priors, t_sim=t_sim)
        else:
            for pool, pend in pending:
                t0 = time.perf_counter()
                with pool.trace.span("simulate", cat="phase",
                                     tid=pool._track,
                                     rows=len(pend.sim_states)):
                    values, priors = self.sim.evaluate(pend.sim_states)
                t_sim = time.perf_counter() - t0
                pool.finish_superstep(pend, values, priors, t_sim=t_sim)

    def _sweep_retirement(self, advanced: set):
        ttl = self.retire_after_ticks
        for pool in self.pools.values():
            if id(pool) in advanced or pool.has_work():
                pool.idle_ticks = 0
            elif not pool.retired:
                pool.idle_ticks += 1
                if ttl is not None and pool.idle_ticks >= ttl:
                    pool.retire()
            if pool.retired:
                self._expire_results(pool)

    def _expire_results(self, pool: ArenaPool):
        """Result TTL (retired pools only): completed results older than
        `result_ttl_ticks` global ticks are dropped from the pool, the
        handle surface and the move log — retirement bounds arena memory,
        this bounds the host-side result ledger.  Expired uids stay in
        `expired_uids` so their handles report status "expired" instead
        of reverting to "unknown".

        Popping `move_log[uid]` only unlinks the LIST from the dict; the
        list object itself is never mutated here.  SearchHandle.moves()
        relies on that: a live iterator holds the list reference it
        first resolved, so expiry mid-iteration stops growth but never
        truncates events the iterator hasn't yielded yet."""
        if self.result_ttl_ticks is None or not pool.completed:
            return
        keep = []
        for res in pool.completed:
            if 0 <= res.done_tick <= self.ticks - self.result_ttl_ticks:
                self.expired_uids.add(res.uid)
                self.results.pop(res.uid, None)
                self.move_log.pop(res.uid, None)
                self._m_expired.inc()
                self.trace.instant("expire", cat="request",
                                   tid=self._track, uid=res.uid)
            else:
                keep.append(res)
        pool.completed[:] = keep

    def run(self, max_ticks: int = 100_000) -> list[SearchResult]:
        """Drain every pool (compatibility surface for the adapters; new
        code drives poll/run_until on the client).  Bounded against the
        CLOCK, not the call count: a fused dispatch advances `ticks` by
        up to K per tick() call, so counting calls would overshoot the
        budget by a factor of K."""
        start = self.ticks
        while self.ticks - start < max_ticks and self.tick():
            pass
        # a clock-budget exit can leave overlap gangs in flight; finish
        # them WITHOUT advancing the clock past the budget
        self.drain_inflight()
        return self.completed

    def drain_inflight(self) -> int:
        """Complete every pool's in-flight overlap gang without advancing
        the global clock (the budget-bound contract of run/result/
        run_until, extended to pipelined gangs).  Returns the number of
        drained supersteps; 0 when overlap is off or nothing is in
        flight."""
        if not self.overlap:
            return 0
        n = 0
        for pool in self.pools.values():
            if not pool.retired:
                n += pool.drain_overlap()
        return n

    # ---- aggregate views ----
    @property
    def completed(self) -> list[SearchResult]:
        done: list[SearchResult] = []
        for key in self._order:
            done.extend(self.pools[key].completed)
        return done

    @property
    def stats(self) -> ServiceStats:
        """Scheduler-wide aggregate of every pool's counters.  `ticks` is
        the core's own monotonic clock (NOT the sum of per-pool attempt
        counters — the per-tick information merge() used to lose), and
        `sim_batches` adds the cross-pool fused evaluate calls the core
        issued itself."""
        total = ServiceStats()
        for pool in self.pools.values():
            total = total.merge(pool.stats)
        total.ticks = self.ticks
        total.sim_batches += self.xpool_batches
        total.max_fused_rows = max(total.max_fused_rows, self.xpool_rows_max)
        return total

    def pool_summaries(self) -> list[dict]:
        """Per-bucket one-liners: shape class, load, session counters."""
        out = []
        for key in self._order:
            pool = self.pools[key]
            s = pool.stats
            out.append({
                "bucket": key, "cfg": pool.cfg, "G": pool.G,
                "queued": len(pool.queue),
                "active": pool.load(),
                "retired": pool.retired,
                "idle_ticks": pool.idle_ticks,
                "supersteps": s.supersteps, "completed": s.completed,
                "session_gathers": s.session_gathers,
                "session_scatters": s.session_scatters,
                "session_reuses": s.session_reuses,
            })
        return out

    def close(self):
        for pool in self.pools.values():
            pool.close()          # flushes sessions; engine is shared
        self.expander.close()     # ... so the core closes it once
