"""ArenaPool — one config bucket's arena, state tables and superstep body.

Middle layer of the service stack.  The three layers after the
SearchClient redesign (client.py has the map):

  client.py          SearchClient / SearchHandle — the public serving API:
                     opaque request handles, streamed per-move events,
                     poll/run_until instead of drain-only run().
  scheduler_core.py  SchedulerCore + SchedulePolicy — global admission
                     across buckets, cold-pool retirement, and the
                     cross-pool fused Simulation batch.
  this module        ArenaPool — one TreeConfig shape class: a G-slot tree
                     arena on one InTreeExecutor, the per-slot
                     StateTables, admission queue, and the BSP superstep
                     body (Selection / Insertion / host expansion / fused
                     Simulation / BackUp, one device program per phase).

Lifecycle of a request:
  queued -> admitted into a free slot (fresh tree + ST, root = seed state)
         -> superstepped until its per-move budget / node cap / saturation
         -> move committed (robust child) and emitted as a MoveEvent to
            the pool's move listener (the client's streaming moves()
            surface), then either
              * evicted with its action trace + root visit distributions, or
              * advanced in place: core.reroot extracts the chosen child's
                subtree (statistics preserved) and the search continues on
                the same slot for its next move.
  A request can also leave early: `cancel(uid)` removes it from the queue
  or frees its slot mid-flight (partial moves are kept on the result),
  and the scheduler core uses the same path for deadline eviction.

The superstep body is split so a scheduler can fuse Simulation across
pools: `begin_superstep()` runs admission, Selection, Insertion and host
expansion and returns the pending step with its simulation rows;
`finish_superstep(pending, values, priors)` scatters the evaluated
values back through finalize / BackUp / move commit.  `superstep()` is
begin + this pool's own `sim.evaluate` + finish — the single-pool case.
Sim-state shapes are env-, not config-, dependent, so a SchedulerCore
serving several shape classes concatenates every pool's pending rows
into ONE `SimulationBackend.evaluate` call per tick and splits the
results back (the cross-pool analogue of the within-pool worker fusion).

Requests may carry their own TreeConfig: any config in the pool's bucket
(core.tree.bucket_key — same X/D/semantics, fanout padded to the shared
Fp lane width) is accepted, and host-side readouts (visit distributions)
use the request's own F.

Active-slot compaction: idle slots execute masked device work under the
uniform arena program — fine at high occupancy, wasteful at low.  Below
the enter threshold the pool opens a persistent CompactionSession
(core.executor): ONE gather copies the A active slots into a dense
pow2-padded sub-arena that stays device-resident across supersteps, with
the scatter back deferred to session close or snapshot reads
(dirty-tracking).  The session is invalidated only on membership changes
— admission, eviction, cancellation, or a reroot rewriting a member slot
— so a stable active set pays one gather + one scatter total instead of
one per superstep.  A separate exit threshold (hysteresis) keeps
occupancy oscillating around the enter threshold from thrashing
gather/scatter.  Per-slot arithmetic is position-independent, so masked,
per-superstep compacted and session execution are all bit-identical.

Cold pools retire: an idle pool's `retire()` closes its session and
releases the arena and StateTables (executor.release()), keeping only
queue/stat/result state; the next submit resurrects it with a fresh
arena.  Retirement is safe exactly because it is only legal when no slot
is occupied — completed results and counters survive, tree state has
nothing live to lose.  The scheduler core drives this off an
idle-superstep TTL (the ROADMAP "bucket arenas are never retired" item).

Multi-device serving (D x G_shard): `n_shards=D` partitions the G slots
into D contiguous runs of G_shard = G // D, one per-device child arena
each (core/sharded.py — shard d's executor is committed to
launch.mesh.serving_devices(D)[d]).  Placement policy: admission fills
the LEAST-LOADED enabled shard first (ties break toward the lowest
shard id, then the lowest free slot — so D=1 reduces exactly to the
historical lowest-free-slot order), which keeps the per-device batch
shapes balanced as requests come and go; `set_shard_enabled(d, False)`
drains a shard for failover — live requests finish, new admissions
route around it.  The superstep body is unchanged: the sharded executor
fans every phase out per device and reassembles, host expansion and the
(cross-pool fused) Simulation batch still span all shards, and fused
K-dispatches run per shard, each to its own escape
(`fused_dispatch`).  Placement is scheduling, not semantics: per-request
results are bit-identical to the single-device pool at any D
(tests/test_executor_matrix.py sharded legs).

Determinism: with a deterministic SimulationBackend the per-slot tree
evolution is bit-identical to a single-tree TreeParallelMCTS run of the
same request (tests/test_service.py) — scheduling changes WHEN a tree's
supersteps happen, never what they compute.

Overlap mode (`overlap=True`) — pipelined supersteps over double-buffered
gangs (the paper's CPU/FPGA stage pipelining, ROADMAP item 3).  The
lock-step superstep serializes host and device: while the
ExpansionEngine / PoolVectorEnv IPC / SimulationBackend run on CPU the
device is idle, and vice versa.  Overlap splits each pool's slots into
`n_gangs` fixed gangs (GangSchedule; gangs partition WITHIN each shard,
so D-sharding composes) and double-buffers: each `begin_superstep` tick
(1) stages the NEXT gang's device half (Selection + Node Insertion,
dispatched async — no host read), (2) collects the IN-FLIGHT gang's
posted expansion batch, and (3) promotes the staged gang — blocking
device readbacks, by then complete, plus the `expand_submit` IPC post —
so that gang's env workers step while the caller evaluates and finishes
the collected gang.  Legality: every device phase is masked per slot and
per-slot arithmetic is position-independent, so interleaving DISJOINT
gangs' phases computes each slot's trajectory bit-identically to
lock-step — overlap changes wall-clock concurrency, never per-request
results (pinned by tests/test_executor_matrix.py overlap legs).  The
clock ticks when a gang superstep begins; `drain_overlap()` completes an
in-flight gang WITHOUT advancing the clock (budget-bound contract), and
runs before any cancel/eviction frees an active slot.  Overlap is
incompatible with active-slot compaction (two gangs in flight would race
the session sub-arena) and composes with fused K-dispatch: per tick one
gang's fused program is submitted (`run_supersteps_submit`) while the
previous gang's escape/accounting runs on host.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core import fixedpoint as fx
from repro.core import reroot
from repro.core.executor import CompactionSession, make_intree_executor
from repro.core.expand import ExpansionEngine
from repro.core.mcts import Environment, SimulationBackend
from repro.core.state_table import StateTable
from repro.core.tree import NULL, TreeConfig, bucket_key
from repro.obs.metrics import NULL_REGISTRY
from repro.obs.trace import NULL_TRACER


def bucket_label(cfg: TreeConfig) -> str:
    """Human-readable bucket tag for metric labels and trace tracks."""
    return f"X{cfg.X}_D{cfg.D}_Fp{cfg.Fp}"


@dataclasses.dataclass
class SearchRequest:
    """One user search: plan `moves` actions from the seed state, spending
    up to `budget` supersteps of p simulations per move.  `cfg` is the
    request's own tree shape — the scheduler routes on it; None means "the
    serving pool's config".  `priority` breaks admission ties (higher
    first, FIFO within a class); `deadline_supersteps` is a global-tick
    budget after which the scheduler core evicts the request with
    whatever moves it has committed."""

    uid: int
    seed: int
    budget: int = 16
    moves: int = 1
    keep_tree: bool = False      # attach the final tree snapshot to the result
    cfg: Optional[TreeConfig] = None
    submitted_at: float = 0.0
    priority: int = 0
    deadline_supersteps: Optional[int] = None
    submit_tick: int = -1        # global tick at submission (set by scheduler)
    deadline_tick: Optional[int] = None  # absolute eviction tick (set by core)


@dataclasses.dataclass
class SearchResult:
    uid: int
    actions: list = dataclasses.field(default_factory=list)
    rewards: list = dataclasses.field(default_factory=list)
    visit_counts: list = dataclasses.field(default_factory=list)  # per move, [F]
    supersteps: int = 0
    terminal: bool = False
    tree_snapshot: Optional[dict] = None
    submitted_at: float = 0.0
    done_at: float = 0.0
    cancelled: bool = False          # cancel() or deadline eviction
    deadline_evicted: bool = False   # the cancel came from a deadline
    done_tick: int = -1              # global tick at completion (result TTL)


@dataclasses.dataclass
class MoveEvent:
    """One committed move of one request, emitted as the reroot commits —
    the streaming unit of SearchHandle.moves().  `last` marks the
    request's final move (its SearchResult is complete)."""

    uid: int
    move_index: int
    action: int
    reward: float
    visit_counts: np.ndarray     # root visit distribution, [F]
    last: bool = False


@dataclasses.dataclass
class _Slot:
    req: SearchRequest
    res: SearchResult
    root_state: np.ndarray
    cfg: TreeConfig              # the request's own config (host readouts)
    moves_done: int = 0
    move_supersteps: int = 0
    prev_size: int = 1


@dataclasses.dataclass
class _PendingStep:
    """A superstep paused at the Simulation boundary: everything
    begin_superstep computed that finish_superstep needs, plus the fused
    sim rows a scheduler may batch across pools."""

    ex: object                   # executor chosen for this tick (arena or sub)
    ex_active: np.ndarray
    rows: np.ndarray             # executor row of each active slot
    act_idx: np.ndarray          # arena slot id of each active slot
    sel_dev: object
    hx: dict                     # {slot: HostExpansion}
    sim_states: np.ndarray       # [sum_p, ...] fused Simulation inputs
    t_intree: float = 0.0        # begin-side wall, folded into the pool's
    t_host: float = 0.0          # timing stats at finish time
    tok: object = None           # open "superstep" span (obs.trace)
    compacted: Optional[bool] = None  # ran on a session sub-arena?  None =
    #                              infer from `ex is not pool.exec` (the
    #                              sharded fused path sets it explicitly:
    #                              its `ex` is a shard child, not a sub)


class GangSchedule:
    """Fixed partition of the G slots into `n_gangs` gangs plus the
    round-robin staging order.  Gangs partition WITHIN each shard
    (contiguous runs of the shard's slots), so every gang keeps balanced
    per-device batches at D > 1.  The schedule is a pure function of
    (G, n_gangs, shard_G) and the occupancy sequence — fixed schedule =>
    deterministic replay (the executor-matrix overlap leg)."""

    def __init__(self, G: int, n_gangs: int, shard_G: Optional[int] = None):
        shard_G = G if shard_G is None else int(shard_G)
        self.n_gangs = max(1, min(int(n_gangs), shard_G))
        self.gang_of = np.array(
            [(g % shard_G) * self.n_gangs // shard_G for g in range(G)],
            np.int64)
        self.cursor = 0   # round-robin position of the next stage

    def mask(self, gang: int) -> np.ndarray:
        return self.gang_of == gang

    def next_gang(self, active: np.ndarray,
                  exclude: Optional[int] = None) -> Optional[int]:
        """Next gang (round-robin from the cursor) holding at least one
        active slot, skipping `exclude` (the in-flight gang).  None when
        no other gang has work."""
        for i in range(self.n_gangs):
            cand = (self.cursor + i) % self.n_gangs
            if cand == exclude:
                continue
            if bool((active & (self.gang_of == cand)).any()):
                self.cursor = (cand + 1) % self.n_gangs
                return cand
        return None


@dataclasses.dataclass
class _StagedGang:
    """A gang whose device half (Selection + Node Insertion) is
    dispatched but not yet read back — the double buffer's async leg."""

    gang: int
    ex_active: np.ndarray        # [G] gang-restricted active mask
    act_idx: np.ndarray          # occupied slots of this gang
    sel_dev: object
    new_nodes_dev: object        # device id block (executor insert_dev)
    t0: float
    tok: object = None           # open "superstep" span on the gang track


@dataclasses.dataclass
class _InflightGang:
    """A promoted gang: device results read back, host expansion batch
    POSTED to the env workers (expand_submit) and running concurrently
    with whatever the main thread does next.  _collect_inflight blocks
    on it and builds the ordinary _PendingStep."""

    gang: int
    ex_active: np.ndarray
    act_idx: np.ndarray
    sel_dev: object
    pexp: object                 # core.expand.PendingExpansion
    t_intree: float
    t_submit: float
    tok: object = None


@dataclasses.dataclass
class ServiceStats:
    supersteps: int = 0
    ticks: int = 0               # scheduler ticks observed (monotonic; a
    #                              bare pool counts its own superstep calls,
    #                              a SchedulerCore overwrites the aggregate
    #                              with its global tick clock)
    admitted: int = 0
    completed: int = 0
    cancelled: int = 0           # cancel() evictions (deadline ones included)
    deadline_evictions: int = 0
    retirements: int = 0         # cold-pool arena releases
    sim_rows: int = 0            # fused simulation-batch rows evaluated
    sim_batches: int = 0         # evaluate() calls this pool issued itself
    max_fused_rows: int = 0
    compacted_supersteps: int = 0  # supersteps run on a gathered sub-arena
    session_gathers: int = 0     # CompactionSession opens (arena -> sub copy)
    session_scatters: int = 0    # sub -> arena write-backs (close/sync)
    session_reuses: int = 0      # supersteps served by an already-resident sub
    occupancy_sum: float = 0.0     # sum of per-superstep A/G (avg = /supersteps)
    fused_dispatches: int = 0    # fused K-superstep device dispatches issued
    fused_supersteps: int = 0    # supersteps that ran inside a fused dispatch
    fused_ran_k: int = 0         # dispatches that ran their full K budget
    fused_escape_commit: int = 0   # dispatches stopped at a move boundary
    fused_escape_expand: int = 0   # dispatches escaped for host expansion
    t_intree: float = 0.0        # select + insert + finalize + backup
    t_host: float = 0.0          # ST / env expansion + scheduling bookkeeping
    t_expand: float = 0.0        # expansion-engine share of t_host
    t_sim: float = 0.0
    # admission-wait histogram: {ticks_waited: n_requests}.  The per-tick
    # information ServiceStats.merge used to lose — fairness metrics
    # (p95 wait per pool and across pools) read this directly.
    wait_supersteps: dict = dataclasses.field(default_factory=dict)

    def merge(self, other: "ServiceStats") -> "ServiceStats":
        """Aggregate across pools (scheduler summary): max_fused_rows is a
        max, wait_supersteps histograms add per bucket, everything else
        sums."""
        out = ServiceStats()
        for f in dataclasses.fields(ServiceStats):
            a, b = getattr(self, f.name), getattr(other, f.name)
            if f.name == "max_fused_rows":
                out.max_fused_rows = max(a, b)
            elif f.name == "wait_supersteps":
                hist = dict(a)
                for k, v in b.items():
                    hist[k] = hist.get(k, 0) + v
                out.wait_supersteps = hist
            else:
                setattr(out, f.name, a + b)
        return out

    def wait_percentile(self, q: float) -> int:
        """q-th percentile (0..100) of the admission-wait histogram."""
        total = sum(self.wait_supersteps.values())
        if total == 0:
            return 0
        need = q / 100.0 * total
        seen = 0
        for wait in sorted(self.wait_supersteps):
            seen += self.wait_supersteps[wait]
            if seen >= need:
                return wait
        return max(self.wait_supersteps)


class ArenaPool:
    """G-slot multi-tree MCTS pool for one config bucket (one host, one
    device program per phase)."""

    def __init__(
        self,
        cfg: TreeConfig,
        env: Environment,
        sim: SimulationBackend,
        G: int,
        p: int,
        executor: str = "faithful",
        alternating_signs: bool = False,
        reuse_subtree: bool = True,
        compact_threshold: float = 0.0,
        compact_exit_threshold: Optional[float] = None,
        persistent_compaction: bool = True,
        expansion: str = "loop",
        supersteps_per_dispatch: int = 1,
        expander: Optional[ExpansionEngine] = None,
        tracer=None,
        metrics=None,
        n_shards: int = 1,
        shard_devices: Optional[list] = None,
        overlap: bool = False,
        n_gangs: int = 2,
    ):
        self.cfg, self.env, self.sim = cfg, env, sim
        self.G, self.p = G, p
        self.executor_name = executor
        self.alternating_signs = alternating_signs
        self.reuse_subtree = reuse_subtree
        # observability: phase spans on this pool's own trace track (gang
        # ticks interleave pools' begin/finish halves — per-pool tracks
        # keep each timeline properly nested), metrics labelled by bucket.
        # Both default to the shared no-op instances.
        self.trace = NULL_TRACER if tracer is None else tracer
        self.registry = NULL_REGISTRY if metrics is None else metrics
        label = bucket_label(cfg)
        self._track = self.trace.track(f"pool:{label}")
        reg = self.registry
        self._m_queue = reg.gauge(
            "service_queue_depth", "requests queued, not yet admitted",
            bucket=label)
        self._m_active = reg.gauge(
            "service_active_slots", "occupied arena slots", bucket=label)
        self._m_admitted = reg.counter(
            "service_admitted_total", "requests admitted into a slot",
            bucket=label)
        self._m_wait = reg.histogram(
            "service_admission_wait_ticks",
            "ticks spent queued before admission", bucket=label)
        self._m_completed = reg.counter(
            "service_completed_total", "requests finished (results emitted)",
            bucket=label)
        self._m_supersteps = reg.counter(
            "service_supersteps_total", "supersteps executed", bucket=label)
        self._m_sim_rows = reg.histogram(
            "service_sim_batch_rows", "rows per fused simulation batch",
            bucket=label)
        self._m_retire = reg.counter(
            "service_retirements_total", "cold-pool arena releases",
            bucket=label)
        self._m_gathers = reg.counter(
            "service_compaction_events_total",
            "compaction-session decisions by kind",
            bucket=label, event="gather")
        self._m_reuses = reg.counter(
            "service_compaction_events_total", bucket=label, event="reuse")
        self._m_scatters = reg.counter(
            "service_compaction_events_total", bucket=label, event="scatter")
        # host-expansion engine: "loop" per-worker env.step, "vector" ONE
        # flattened step_batch over all slots' pending expansions, "pool"
        # the process-pool scalar fallback (core.expand) — bit-identical.
        # A scheduler serving several pools passes one shared engine in.
        self._owns_expander = expander is None
        self.expander = ExpansionEngine(
            env, expansion, tracer=tracer, metrics=metrics) \
            if expander is None else expander
        # occupancy A/G at or below this gathers active slots into a dense
        # sub-arena for the device phases.  Opt-in (0.0 = always masked).
        # Hysteresis: once compacted, the pool stays compacted until
        # occupancy rises above `compact_exit_threshold` (>= enter; default
        # equal, i.e. no hysteresis) so oscillation around the enter
        # threshold cannot thrash gather/scatter.
        self.compact_threshold = compact_threshold
        self.compact_exit_threshold = (
            compact_threshold if compact_exit_threshold is None
            else compact_exit_threshold)
        assert self.compact_exit_threshold >= self.compact_threshold, (
            "hysteresis exit threshold must be >= enter threshold")
        # keep the dense sub-arena device-resident across supersteps
        # (scatter only on membership change / snapshot read); False
        # restores the per-superstep gather/scatter for comparison
        self.persistent_compaction = persistent_compaction
        # multi-device serving: D per-device shard runs of G_shard slots
        # each (module docstring, "Multi-device serving").  D=1 is the
        # historical single-arena pool, bit for bit.
        self.n_shards = max(1, int(n_shards))
        if G % self.n_shards:
            raise ValueError(
                f"G={G} must be a multiple of n_shards={self.n_shards}")
        self.shard_G = G // self.n_shards
        self.shard_devices = shard_devices
        self._shard_enabled = [True] * self.n_shards
        self.exec = make_intree_executor(cfg, G, executor,
                                         n_shards=self.n_shards,
                                         devices=shard_devices)
        self.sts = [StateTable(cfg.X, env.state_shape, env.state_dtype)
                    for _ in range(G)]
        self.slots: list[Optional[_Slot]] = [None] * G
        self.queue: list[SearchRequest] = []
        self.completed: list[SearchResult] = []
        self.stats = ServiceStats()
        self.last_decision: dict = {}   # per-superstep occupancy/compaction
        self._session: Optional[CompactionSession] = None
        self._compacting = False        # hysteresis state
        # scheduler hooks: a SchedulerCore installs its global tick clock
        # (admission-wait attribution), an admission cap (per-bucket G
        # sizing), deadline-first admission order, and the move/result
        # listeners the client's handle surface is built on
        self.clock: Optional[Callable[[], int]] = None
        self.admit_limit: Optional[int] = None
        self.deadline_first = False
        self.move_listener: Optional[Callable[[MoveEvent], None]] = None
        self.result_listener: Optional[Callable[[SearchResult], None]] = None
        # cold-pool retirement state (see retire())
        self.retired = False
        self.idle_ticks = 0
        # fused K-superstep device dispatch (repro.core.fused): K > 1 runs
        # up to K supersteps per device program when the executor, env and
        # sim backend all have device legs (fused_capable); K = 1 keeps
        # the phase-by-phase path — the oracle the fused path is
        # differential-tested against.
        self.supersteps_per_dispatch = max(1, int(supersteps_per_dispatch))
        # overlap mode: pipelined supersteps over double-buffered gangs
        # (module docstring, "Overlap mode").  Incompatible with active-
        # slot compaction: a resident session sub-arena cannot track two
        # gangs in flight.
        self.overlap = bool(overlap)
        self.n_gangs = max(1, int(n_gangs))
        if self.overlap and compact_threshold > 0.0:
            raise ValueError(
                "overlap=True is incompatible with active-slot compaction "
                "(compact_threshold > 0): a resident session sub-arena "
                "would go stale under two gangs in flight")
        self.gangs = (GangSchedule(G, self.n_gangs, self.shard_G)
                      if self.overlap else None)
        self._inflight: Optional[_InflightGang] = None
        self._inflight_fused: Optional[dict] = None
        self._gang_tids: dict = {}
        # overlap busy-ratio bookkeeping: wall seconds of overlap ticks,
        # and how much of them the main thread spent BLOCKED on the env
        # workers (host side) / on device readbacks (device side)
        self._ov_wall = 0.0
        self._ov_wait_host = 0.0
        self._ov_wait_dev = 0.0
        if self.overlap:
            self._m_busy_host = reg.gauge(
                "service_overlap_busy_ratio",
                "fraction of overlap-tick wall the main thread was not "
                "blocked, by waiting side", bucket=label, side="host")
            self._m_busy_dev = reg.gauge(
                "service_overlap_busy_ratio", bucket=label, side="device")
            self._m_ov_eff = reg.histogram(
                "service_overlap_efficiency",
                "per-tick percent of wall not spent blocked on env "
                "workers or device readbacks", bucket=label)
        # fixed per-slot finalize width (vmapped finalize needs one shape)
        self.K = p * cfg.Fp if cfg.expand_all else p

    # ---- admission ----
    def submit(self, req: SearchRequest):
        if req.cfg is not None and bucket_key(req.cfg) != bucket_key(self.cfg):
            raise ValueError(
                f"request uid={req.uid} config {req.cfg} is outside this "
                f"pool's bucket {bucket_key(self.cfg)} — route it through "
                f"service.client.SearchClient")
        if not req.submitted_at:
            req.submitted_at = time.perf_counter()
        if req.submit_tick < 0:
            req.submit_tick = self._now()
        if self.retired:
            self._resurrect()
        self.queue.append(req)
        self.trace.async_begin(
            "request", req.uid, cat="request", tid=self._track,
            uid=req.uid, seed=req.seed, budget=req.budget, moves=req.moves)
        self.trace.instant("submit", cat="request", tid=self._track,
                           uid=req.uid)

    def _now(self) -> int:
        return self.clock() if self.clock is not None else self.stats.ticks

    def _admit_rank(self, req: SearchRequest, i: int) -> tuple:
        """Admission order: priority class first; within a class, earliest
        deadline first when the scheduler policy asked for it
        (deadline_first), else strict FIFO.  Default requests (priority 0,
        no deadlines) reduce to the original FIFO pop."""
        urgency = (-req.deadline_tick
                   if self.deadline_first and req.deadline_tick is not None
                   else float("-inf"))
        return (req.priority, urgency, -i)

    def shard_of(self, g: int) -> int:
        """Owning shard of slot g (contiguous D-way partition)."""
        return int(g) // self.shard_G

    def shard_loads(self) -> list:
        """Occupied-slot count per shard — the placement signal."""
        loads = [0] * self.n_shards
        for g, s in enumerate(self.slots):
            if s is not None:
                loads[g // self.shard_G] += 1
        return loads

    def set_shard_enabled(self, shard: int, enabled: bool = True):
        """Failover lever: a disabled shard accepts no NEW admissions
        (its live requests run to completion) — placement routes around
        it until it is re-enabled."""
        self._shard_enabled[int(shard)] = bool(enabled)

    def _place_slot(self) -> Optional[int]:
        """Cross-device placement: the lowest free slot of the
        least-loaded ENABLED shard (ties: lowest shard id).  With D=1
        this is exactly the historical lowest-free-slot order."""
        loads = self.shard_loads()
        best = None
        for d in range(self.n_shards):
            if not self._shard_enabled[d]:
                continue
            lo = d * self.shard_G
            free = next((g for g in range(lo, lo + self.shard_G)
                         if self.slots[g] is None), None)
            if free is None:
                continue
            if best is None or loads[d] < loads[best[0]]:
                best = (d, free)
        return None if best is None else best[1]

    def _admit(self):
        limit = self.G if self.admit_limit is None \
            else max(0, min(self.admit_limit, self.G))
        active = sum(s is not None for s in self.slots)
        while self.queue and active < limit:
            g = self._place_slot()
            if g is None:   # every enabled shard is full
                break
            i = max(range(len(self.queue)),
                    key=lambda j: self._admit_rank(self.queue[j], j))
            req = self.queue.pop(i)
            res = SearchResult(uid=req.uid, submitted_at=req.submitted_at)
            s0 = self.env.initial_state(req.seed)
            na = self.env.num_actions(s0)
            if na == 0:  # degenerate: nothing to search, slot stays free
                res.terminal = True
                self._finish(res)
                continue
            self.exec.reset_slot(g, na)
            self.sts[g].flush(s0)
            self.slots[g] = _Slot(req=req, res=res, root_state=s0,
                                  cfg=req.cfg if req.cfg is not None
                                  else self.cfg)
            self.stats.admitted += 1
            wait = max(0, self._now() - max(req.submit_tick, 0))
            self.stats.wait_supersteps[wait] = (
                self.stats.wait_supersteps.get(wait, 0) + 1)
            self._m_admitted.inc()
            self._m_wait.observe(wait)
            self.trace.instant("admit", cat="request", tid=self._track,
                               uid=req.uid, slot=g, shard=g // self.shard_G,
                               wait=wait)
            active += 1

    def _active(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots], bool)

    def load(self) -> int:
        """Occupied-slot count — the public load accessor (frontends and
        schedulers must not reach into _active)."""
        return int(np.sum(self._active()))

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def holds(self, uid: int) -> bool:
        """True while `uid` occupies a slot.  Safe on retired pools — a
        released arena holds nothing, and the probe never touches slot
        state that retirement dropped (SearchHandle.status uses this
        instead of reaching into `slots`)."""
        if self.retired:
            return False
        return any(s is not None and s.req.uid == uid for s in self.slots)

    def deadline_ticks(self) -> list:
        """Absolute deadline ticks of every queued and in-flight request.
        Safe on retired pools: retirement is only legal with no occupied
        slot, so only the queue (which survives resurrection-on-submit)
        is consulted there (DeadlineAwarePolicy orders pools with this
        instead of probing `slots` directly)."""
        out = [r.deadline_tick for r in self.queue
               if r.deadline_tick is not None]
        if not self.retired:
            out += [s.req.deadline_tick for s in self.slots
                    if s is not None and s.req.deadline_tick is not None]
        return out

    # ---- cancellation (client cancel / scheduler deadline eviction) ----
    def cancel(self, uid: int, reason: str = "cancel") -> bool:
        """Evict a request before it completes.  Queued requests leave
        with an empty (cancelled) result; an in-flight request keeps the
        moves it already committed.  Returns False when the uid is not
        queued or active here (already done, or never submitted)."""
        for i, req in enumerate(self.queue):
            if req.uid == uid:
                self.queue.pop(i)
                res = SearchResult(uid=uid, submitted_at=req.submitted_at)
                self._mark_cancelled(res, reason)
                self._finish(res)
                return True
        for g, slot in enumerate(self.slots):
            if slot is not None and slot.req.uid == uid:
                # an in-flight gang holding this slot must finish first:
                # its applied selection/insertion reference the slot, and
                # freeing it mid-pipeline would strand virtual losses and
                # crash the gang's _commit_moves
                if self.overlap:
                    self.drain_overlap()
                    if self.slots[g] is None or self.slots[g].req.uid != uid:
                        # the drained superstep completed this request
                        return True
                # freeing the slot is a membership change: a resident
                # session spanning it must scatter + close first
                self._invalidate_session(g)
                self._mark_cancelled(slot.res, reason)
                self._finish(slot.res)
                self.slots[g] = None
                return True
        return False

    def _mark_cancelled(self, res: SearchResult, reason: str):
        res.cancelled = True
        self.stats.cancelled += 1
        if reason == "deadline":
            res.deadline_evicted = True
            self.stats.deadline_evictions += 1
        self.registry.counter(
            "service_evictions_total", "requests cancelled or evicted",
            bucket=bucket_label(self.cfg), reason=reason).inc()
        self.trace.instant("evict" if reason == "deadline" else "cancel",
                           cat="request", tid=self._track, uid=res.uid,
                           reason=reason)

    # ---- cold-pool retirement ----
    def retire(self) -> bool:
        """Release the arena and StateTables of an idle pool (queue empty,
        no occupied slot): the CompactionSession closes, the executor's
        device arrays are released, and only queue/result/stat state
        remains.  The next submit resurrects the pool with a fresh arena —
        legal precisely because nothing live was resident."""
        if self.retired or self.has_work():
            return False
        self._close_session()
        self.exec.release()
        self.exec = None
        self.sts = None
        # drop the per-slot list too (fresh, all-free): probes that still
        # reach a retired pool must never see stale slot objects, and the
        # retired-safe accessors (holds / deadline_ticks / shard_loads)
        # stay well-defined
        self.slots = [None] * self.G
        self.retired = True
        self.stats.retirements += 1
        self._m_retire.inc()
        self.trace.instant("retire", cat="pool", tid=self._track)
        return True

    def _resurrect(self):
        self.exec = make_intree_executor(self.cfg, self.G,
                                         self.executor_name,
                                         n_shards=self.n_shards,
                                         devices=self.shard_devices)
        self.sts = [StateTable(self.cfg.X, self.env.state_shape,
                               self.env.state_dtype) for _ in range(self.G)]
        self.retired = False
        self.idle_ticks = 0
        self._compacting = False   # fresh arena, fresh hysteresis state
        self.trace.instant("resurrect", cat="pool", tid=self._track)

    # ---- session plumbing ----
    def _close_session(self):
        ses, self._session = self._session, None
        if ses is not None and ses.close():
            self.stats.session_scatters += 1
            self._m_scatters.inc()

    def _sizes(self) -> np.ndarray:
        ses = self._session
        sizes = np.asarray(self.exec.sizes()).copy()
        if ses is not None and ses.open and ses.dirty:
            sizes[ses.slot_idx] = np.asarray(ses.sub.sizes())[: ses.A]
        return sizes

    def _best_actions(self) -> np.ndarray:
        ses = self._session
        best = np.asarray(self.exec.best_actions()).copy()
        if ses is not None and ses.open and ses.dirty:
            best[ses.slot_idx] = np.asarray(ses.sub.best_actions())[: ses.A]
        return best

    def _slot_snapshot(self, g: int) -> dict:
        """Snapshot through the session: a dirty sub-arena is scattered
        back first (the snapshot must see the latest supersteps), then the
        full arena is read as usual."""
        ses = self._session
        if ses is not None and ses.owns(int(g)) and ses.sync():
            self.stats.session_scatters += 1
            self._m_scatters.inc()
        return self.exec.slot_snapshot(g)

    def _invalidate_session(self, g: int):
        """A host-side write (reroot / reset / eviction) is about to touch
        slot g on the full arena — a resident sub-arena copy of it would go
        stale, so the session ends here."""
        ses = self._session
        if ses is not None and ses.owns(int(g)):
            self._close_session()

    # ---- occupancy decision: masked full arena vs resident sub-arena ----
    def _pick_execution(self, active: np.ndarray):
        """Return (executor, exec_active, rows, act_idx): `rows[i]` is the
        arena row carrying active slot `act_idx[i]` on the chosen executor
        (identity when masked, dense prefix when compacted)."""
        act_idx = np.flatnonzero(active)
        A = len(act_idx)
        Gc = 1 << (A - 1).bit_length()     # pow2 pad: bounded program cache
        thresh = (self.compact_exit_threshold if self._compacting
                  else self.compact_threshold)
        compacted = (self.compact_threshold > 0.0
                     and A <= thresh * self.G
                     and Gc < self.G)
        self._compacting = compacted
        session_state = None
        if compacted:
            ses = self._session
            if ses is not None and ses.matches(act_idx, Gc):
                session_state = "resident"
                self.stats.session_reuses += 1
                self._m_reuses.inc()
            else:
                self._close_session()
                ses = self._session = self.exec.open_session(
                    act_idx, Gc, tracer=self.trace, tid=self._track)
                session_state = "gather"
                self.stats.session_gathers += 1
                self._m_gathers.inc()
            ses.mark_superstep()
        else:
            self._close_session()
        self.last_decision = {
            "A": A, "G": self.G, "occupancy": A / self.G,
            "compacted": compacted, "G_exec": Gc if compacted else self.G,
            "session": session_state,
        }
        if compacted:
            return (self._session.sub, np.arange(Gc) < A,
                    np.arange(A), act_idx)
        return self.exec, active, act_idx, act_idx

    # ---- overlap pipeline (double-buffered gangs) ----
    def _gang_track(self, gang: int) -> int:
        """Per-gang Perfetto track: gang supersteps interleave, so each
        gang's spans nest on its own timeline."""
        tid = self._gang_tids.get(gang)
        if tid is None:
            tid = self.trace.track(
                f"pool:{bucket_label(self.cfg)}:gang{gang}")
            self._gang_tids[gang] = tid
        return tid

    def _stage(self, gang: int, active: np.ndarray) -> _StagedGang:
        """Dispatch one gang's device half (Selection + Node Insertion)
        WITHOUT reading anything back: JAX async dispatch queues the
        programs and returns; the blocking readbacks wait until
        _promote."""
        t0 = time.perf_counter()
        gmask = active & self.gangs.mask(gang)
        act_idx = np.flatnonzero(gmask)
        tid = self._gang_track(gang)
        tok = self.trace.begin("superstep", cat="phase", tid=tid,
                               tick=self._now(), gang=gang,
                               slots=len(act_idx))
        with self.trace.span("select", cat="phase", tid=tid,
                             slots=len(act_idx), gang=gang):
            sel_dev = self.exec.selection(gmask, self.p)
            new_dev = self.exec.insert_dev(gmask, sel_dev)
            if self.trace.enabled:
                self.exec.block()   # honesty rule: fence only when tracing
        return _StagedGang(gang=gang, ex_active=gmask, act_idx=act_idx,
                           sel_dev=sel_dev, new_nodes_dev=new_dev,
                           t0=t0, tok=tok)

    def _promote(self, st: _StagedGang) -> _InflightGang:
        """Staged -> in-flight: blocking device readbacks (selection +
        inserted ids, complete by now) and the expansion-batch POST.
        From here the gang's env workers step concurrently with whatever
        the main thread does next (evaluate/finish of another gang)."""
        t0 = time.perf_counter()
        sel = self.exec.sel_to_host(st.sel_dev)
        new_nodes = self.exec.insert_host(st.new_nodes_dev)
        t_dev = time.perf_counter() - t0
        self._ov_wait_dev += t_dev
        pexp = self.expander.expand_submit(
            [(g, self.sts[g], {k: v[g] for k, v in sel.items()},
              new_nodes[g]) for g in st.act_idx],
            tid=self._gang_tids.get(st.gang, self._track))
        t1 = time.perf_counter()
        # in-tree wall ~= the blocking device readback; the dispatch
        # itself returned immediately at stage time
        return _InflightGang(gang=st.gang, ex_active=st.ex_active,
                             act_idx=st.act_idx, sel_dev=st.sel_dev,
                             pexp=pexp, t_intree=t_dev,
                             t_submit=(t1 - t0) - t_dev, tok=st.tok)

    def _collect_inflight(self) -> _PendingStep:
        """Block on the in-flight gang's posted expansion batch and build
        the ordinary _PendingStep the caller evaluates and finishes."""
        inf, self._inflight = self._inflight, None
        t0 = time.perf_counter()
        hx = self.expander.expand_collect(
            inf.pexp, tid=self._gang_tids.get(inf.gang, self._track))
        t_wait = time.perf_counter() - t0
        self._ov_wait_host += t_wait
        self.stats.t_expand += inf.t_submit + t_wait
        sim_states = np.concatenate([hx[g].sim_states for g in inf.act_idx])
        return _PendingStep(
            ex=self.exec, ex_active=inf.ex_active, rows=inf.act_idx,
            act_idx=inf.act_idx, sel_dev=inf.sel_dev, hx=hx,
            sim_states=sim_states, t_intree=inf.t_intree,
            t_host=inf.t_submit + t_wait, tok=inf.tok, compacted=False)

    def _begin_overlap(self) -> Optional[_PendingStep]:
        """One overlap tick: stage + promote the next gang (device half
        dispatched, expansion batch posted), then collect the in-flight
        gang.  Returns the collected gang's pending step (exactly one per
        tick, like lock-step); with a single active gang the pipeline
        self-drains each tick and degenerates to lock-step."""
        if self._inflight_fused is not None:
            # mode switch (a scheduler deadline cap dropped K to 1):
            # finish the staged fused gang before pipelining phase-path
            # gangs, or the same slots could select twice concurrently
            self.drain_overlap()
        self.stats.ticks += 1
        t_tick0 = time.perf_counter()
        self._admit()
        self._m_queue.set(len(self.queue))
        active = self._active()
        self._m_active.set(int(active.sum()))
        if not active.any():
            # an in-flight gang implies occupied slots, so the pipeline
            # is necessarily empty here
            return None
        if self._inflight is None:   # warm-up: fill the double buffer
            self._inflight = self._promote(
                self._stage(self.gangs.next_gang(active), active))
        # stage AND promote the next gang before blocking on the
        # in-flight IPC: the promoted gang's expansion batch then runs in
        # the env workers across the in-flight gang's entire collect wait
        # plus the caller's evaluate + finish — the widest window the
        # tick can offer.  (Promoting after the collect would shrink the
        # window to evaluate + finish alone and expose most of the IPC
        # wait; the data dependencies are identical either way, since
        # promote never touches the in-flight gang's slots.)
        nxt = self.gangs.next_gang(active, exclude=self._inflight.gang)
        promoted = None if nxt is None else self._promote(
            self._stage(nxt, active))
        pend = self._collect_inflight()
        self._inflight = promoted
        wall = time.perf_counter() - t_tick0
        self._ov_wall += wall
        if self._ov_wall > 0:
            self._m_busy_host.set(1.0 - self._ov_wait_host / self._ov_wall)
            self._m_busy_dev.set(1.0 - self._ov_wait_dev / self._ov_wall)
        self._m_ov_eff.observe(100.0 * max(
            0.0, 1.0 - (self._ov_wait_host + self._ov_wait_dev)
            / max(self._ov_wall, 1e-12)))
        return pend

    def drain_overlap(self) -> int:
        """Complete any in-flight gang WITHOUT advancing the clock: the
        budget-bound contract (run/result/run_until max_ticks) and every
        path that frees an active slot (cancel, deadline eviction, close)
        must not leave a gang's applied selection/insertion unfinished.
        Returns the number of supersteps completed (0 when idle)."""
        n = 0
        inf_f, self._inflight_fused = self._inflight_fused, None
        if inf_f is not None:
            n = max(n, self._fused_collect_gang(inf_f))
        if self._inflight is not None:
            pend = self._collect_inflight()
            with self.trace.span("simulate", cat="phase", tid=self._track,
                                 rows=len(pend.sim_states), drain=True):
                values, priors = self._sim_evaluate(pend.sim_states)
            self.finish_superstep(pend, values, priors)
            n += 1
        return n

    # ---- superstep, paused at the Simulation boundary ----
    def begin_superstep(self) -> Optional[_PendingStep]:
        """Admission + Selection + Insertion + host expansion.  Returns
        the pending step carrying the fused simulation rows, or None when
        no slot is occupied.  The caller evaluates the rows (alone or
        fused with other pools') and hands them to finish_superstep."""
        if self.overlap:
            return self._begin_overlap()
        self.stats.ticks += 1
        tok = self.trace.begin("superstep", cat="phase", tid=self._track,
                               tick=self._now())
        self._admit()
        self._m_queue.set(len(self.queue))
        active = self._active()
        self._m_active.set(int(active.sum()))
        if not active.any():
            self.trace.end(tok)
            return None
        t0 = time.perf_counter()
        ex, ex_active, rows, act_idx = self._pick_execution(active)
        with self.trace.span("select", cat="phase", tid=self._track,
                             slots=len(act_idx)):
            sel_dev = ex.selection(ex_active, self.p)
            sel = ex.sel_to_host(sel_dev)                     # [Ge, p, ...]
            new_nodes = ex.insert(ex_active, sel_dev)         # [Ge, p, Fp]
            if self.trace.enabled:
                ex.block()   # attribute device time to select, honestly
        t1 = time.perf_counter()

        # host expansion: every slot's pending expansions through the
        # engine (one flattened env batch in vector/pool mode); the fused
        # Simulation rows are the pending step's hand-off.  The engine
        # emits the "expand" span on this pool's track.
        hx = self.expander.expand(
            [(g, self.sts[g], {k: v[r] for k, v in sel.items()},
              new_nodes[r]) for r, g in zip(rows, act_idx)],
            tid=self._track)
        t_x = time.perf_counter()
        self.stats.t_expand += t_x - t1
        sim_states = np.concatenate([hx[g].sim_states for g in act_idx])
        t2 = time.perf_counter()
        return _PendingStep(
            ex=ex, ex_active=ex_active, rows=rows, act_idx=act_idx,
            sel_dev=sel_dev, hx=hx, sim_states=sim_states,
            t_intree=t1 - t0, t_host=t2 - t1, tok=tok)

    def finish_superstep(self, pend: _PendingStep, values, priors,
                         t_sim: float = 0.0, own_batch: bool = True):
        """Scatter evaluated values back: finalize + BackUp across all
        slots at once, then commit any finished moves.  `own_batch` is
        False when a scheduler core evaluated this pool's rows inside a
        cross-pool fused batch (the core counts that batch once)."""
        ex, rows, act_idx = pend.ex, pend.rows, pend.act_idx
        p, cfg = self.p, self.cfg
        Ge = ex.G
        self.stats.sim_rows += len(pend.sim_states)
        self.stats.t_sim += t_sim
        if own_batch:
            self.stats.sim_batches += 1
        self.stats.max_fused_rows = max(self.stats.max_fused_rows,
                                        len(pend.sim_states))
        t3 = time.perf_counter()
        values_fx = np.asarray(fx.encode(np.asarray(values)), np.int32)
        fin_nodes = np.full((Ge, self.K), NULL, np.int32)
        fin_na = np.zeros((Ge, self.K), np.int32)
        fin_term = np.zeros((Ge, self.K), np.int32)
        fin_pp = np.full((Ge, p), NULL, np.int32)
        fin_pf = np.zeros((Ge, p, cfg.Fp), np.int32)
        sim_nodes = np.zeros((Ge, p), np.int32)
        vals = np.zeros((Ge, p), np.int32)
        # batched scatter over all active slots at once (the per-slot
        # padded_finalize_args loop, vectorized; bit-identity pinned by
        # the executor matrix): ragged per-slot finalize entries land at
        # (repeated row, dense prefix position)
        hxs = [pend.hx[g] for g in act_idx]
        rows_arr = np.asarray(rows, np.int64)
        A = len(hxs)
        sim_nodes[rows_arr] = np.stack([h.sim_nodes for h in hxs])
        vals[rows_arr] = values_fx.reshape(A, p)
        counts = np.fromiter((len(h.fin_nodes) for h in hxs), np.int64, A)
        total = int(counts.sum())
        if total:
            rr = np.repeat(rows_arr, counts)
            pos = np.arange(total) - np.repeat(np.cumsum(counts) - counts,
                                               counts)
            fin_nodes[rr, pos] = np.concatenate(
                [h.fin_nodes for h in hxs if h.fin_nodes])
            fin_na[rr, pos] = np.concatenate(
                [h.fin_na for h in hxs if h.fin_na])
            fin_term[rr, pos] = np.concatenate(
                [h.fin_term for h in hxs if h.fin_term])
        if priors is not None:
            pw = np.fromiter((len(h.prior_workers) for h in hxs), np.int64,
                             A)
            tp = int(pw.sum())
            if tp:
                rr2 = np.repeat(rows_arr, pw)
                pos2 = np.arange(tp) - np.repeat(np.cumsum(pw) - pw, pw)
                fin_pp[rr2, pos2] = np.concatenate(
                    [h.prior_parents for h in hxs if h.prior_parents])
                # global prior row of slot i's worker w is i*p + w
                gw = np.concatenate(
                    [np.asarray(h.prior_workers, np.int64) + i * p
                     for i, h in enumerate(hxs) if h.prior_workers])
                pr = np.asarray(priors)[gw]
                padded = np.zeros((tp, cfg.Fp), np.float32)
                padded[:, : pr.shape[1]] = pr
                fin_pf[rr2, pos2] = np.asarray(fx.encode(padded), np.int32)
        t4 = time.perf_counter()

        with self.trace.span("backup", cat="phase", tid=self._track,
                             slots=len(act_idx)):
            ex.finalize(fin_nodes, fin_na, fin_term, fin_pp, fin_pf)
            ex.backup(pend.ex_active, pend.sel_dev, sim_nodes, vals,
                      self.alternating_signs)
            if self.trace.enabled:
                ex.block()   # fence: device backup time stays in this span
        compacted = (pend.compacted if pend.compacted is not None
                     else ex is not self.exec)
        if compacted:
            self.stats.compacted_supersteps += 1
            if not self.persistent_compaction:
                # per-superstep mode: scatter (and re-gather next tick)
                self._close_session()
        t5 = time.perf_counter()

        self.stats.supersteps += 1
        self.stats.occupancy_sum += len(act_idx) / self.G
        self.stats.t_intree += pend.t_intree + (t5 - t4)
        self.stats.t_host += pend.t_host + (t4 - t3)
        self._m_supersteps.inc()
        if own_batch:
            self._m_sim_rows.observe(len(pend.sim_states))

        self._commit_moves(act_idx)
        if pend.tok is not None:
            self.trace.end(pend.tok)

    def _sim_evaluate(self, states):
        """One simulation batch, routed through the backend's
        non-blocking submit/collect split when it has one (repro.sim
        SimServer / CachedSimBackend) so every pool-side call site feeds
        the same serving admission window; identical results either way
        (for the split backends evaluate() IS submit + collect)."""
        from repro.envs.device import has_async_sim

        if has_async_sim(self.sim):
            return self.sim.collect(self.sim.submit(states))
        return self.sim.evaluate(states)

    # ---- one fused superstep over all occupied slots ----
    def superstep(self) -> bool:
        pend = self.begin_superstep()
        if pend is None:
            return False
        t2 = time.perf_counter()
        with self.trace.span("simulate", cat="phase", tid=self._track,
                             rows=len(pend.sim_states)):
            values, priors = self._sim_evaluate(pend.sim_states)
        t_sim = time.perf_counter() - t2
        self.finish_superstep(pend, values, priors, t_sim=t_sim)
        return True

    # ---- fused K-superstep device dispatch (repro.core.fused) ----
    def fused_capable(self) -> bool:
        """True when this pool can run fused dispatches: a device
        executor (reference keeps the phase-by-phase oracle), a
        device-evaluable env twin, a device value backend, and no
        expand-all priors (those force the host expansion path).  A
        sharded executor is fused-capable when every per-device child
        is (the fused program runs per shard, never across shards)."""
        from repro.envs.device import has_device_env, has_device_sim

        ex = self.exec
        if ex is None:
            return False
        shards = getattr(ex, "shards", None)
        if shards is not None:
            fused_ok = all(hasattr(c, "run_supersteps")
                           for c, _, _ in shards)
        else:
            fused_ok = hasattr(ex, "run_supersteps")
        return (not self.cfg.expand_all
                and fused_ok
                and has_device_env(self.env)
                and has_device_sim(self.sim))

    def fused_dispatch(self, max_supersteps: Optional[int] = None) -> int:
        """Run up to min(supersteps_per_dispatch, max_supersteps) BSP
        supersteps in ONE compiled device program, escaping early at a
        move-commit boundary or an expansion the device env twin cannot
        resolve (that superstep is then completed through the ordinary
        host path, so every escape stays on the K=1 oracle trajectory).
        Falls back to a single phase-by-phase superstep when K <= 1 or
        the pool is not fused-capable.  Returns the number of complete
        supersteps executed (0 when no slot is occupied).

        At D > 1 each shard dispatches its OWN fused program on its own
        device, runs to its own escape, and handles its own
        commits/escapes before the next shard dispatches — a commit
        boundary only stops the shard that hit it, so the scheduler
        clock advances by the max over shards.  Per-slot trajectories
        are unchanged (commit boundaries are slot-local; the lockstep
        stop inside a program only decides dispatch grouping), so
        per-request results stay bit-identical to D=1; pool-total
        dispatch/superstep counters become per-shard sums."""
        K = self.supersteps_per_dispatch
        if max_supersteps is not None:
            K = min(K, max(1, int(max_supersteps)))
        if K <= 1 or not self.fused_capable():
            return 1 if self.superstep() else 0
        if self.overlap:
            return self._fused_overlap_tick(K)
        self.stats.ticks += 1
        tok = self.trace.begin("fused-dispatch", cat="phase",
                               tid=self._track, tick=self._now(), k=K)
        self._admit()
        self._m_queue.set(len(self.queue))
        active = self._active()
        self._m_active.set(int(active.sum()))
        if not active.any():
            self.trace.end(tok)
            return 0
        if self.n_shards > 1:
            # Sharded fused path: run masked on the per-device arenas,
            # never on a session sub.  A shard's move commit writes the
            # full arena (reroot/reset/evict), which would silently
            # stale a resident sub-arena other shards still dispatch on
            # this tick — so close any session up front.  (The classic
            # path keeps compaction: there a full superstep spans every
            # shard before any commit.)  Supersteps are
            # grouping-independent, so results are unchanged.
            self._close_session()
            self._compacting = False
            act_idx = np.flatnonzero(active)
            self.last_decision = {
                "A": len(act_idx), "G": self.G,
                "occupancy": len(act_idx) / self.G, "compacted": False,
                "G_exec": self.G, "session": None,
            }
            ns = []
            for child, lo, n_run in self.exec.shards:
                in_shard = (act_idx >= lo) & (act_idx < lo + n_run)
                if not in_shard.any():
                    continue
                c_idx = act_idx[in_shard]
                c_active = np.zeros(child.G, bool)
                c_active[c_idx - lo] = True
                ns.append(self._fused_dispatch_one(
                    child, c_active, c_idx - lo, c_idx, K,
                    on_sub=False, tok=None))
            self.trace.end(tok)
            return max(ns) if ns else 0
        ex, ex_active, rows, act_idx = self._pick_execution(active)
        return self._fused_dispatch_one(ex, ex_active, rows, act_idx, K,
                                        on_sub=ex is not self.exec,
                                        tok=tok)

    def _fused_dispatch_one(self, ex, ex_active, rows, act_idx, K: int,
                            on_sub: bool, tok) -> int:
        """One fused device dispatch on one executor view: the whole
        arena at D=1 (masked, or a session sub when `on_sub`), or a
        single shard's child at D>1 (`rows` are executor-local, while
        `act_idx` stays in global slot ids).  Handles its own escape —
        a commit exit replays _commit_moves exactly like the K=1 path,
        an expansion escape completes the partial superstep through the
        ordinary host path — and returns the superstep count.  `tok` is
        the open fused-dispatch span when this call owns it (None on
        the sharded path, where the caller's loop holds one span over
        all shards)."""
        t0 = time.perf_counter()
        budget_left, states, start_size = self._fused_upload(
            ex, rows, act_idx)
        disp = ex.run_supersteps(ex_active, self.p, K, self.env, self.sim,
                                 states, budget_left,
                                 self.alternating_signs)
        return self._fused_finish_one(ex, ex_active, rows, act_idx, disp,
                                      start_size, on_sub, tok, t0)

    def _fused_upload(self, ex, rows, act_idx):
        """Host half of a fused dispatch's inputs: per-row remaining move
        budgets + ONE upload of the dispatched rows' ST images; the
        buffer stays device-resident for the whole dispatch (fused
        supersteps cost zero H2D copies)."""
        Ge = ex.G
        budget_left = np.zeros(Ge, np.int32)
        states = np.zeros((Ge, self.cfg.X) + tuple(self.env.state_shape),
                          self.env.state_dtype)
        start_size = np.ones(Ge, np.int64)
        for r, g in zip(rows, act_idx):
            slot = self.slots[g]
            budget_left[r] = slot.req.budget - slot.move_supersteps
            states[r] = self.sts[g].data
            start_size[r] = slot.prev_size
        return budget_left, states, start_size

    def _fused_finish_one(self, ex, ex_active, rows, act_idx, disp,
                          start_size, on_sub: bool, tok, t0: float) -> int:
        """Accounting + escape handling for one collected fused dispatch
        (the post-device half of _fused_dispatch_one; the overlap path
        reaches it through run_supersteps_submit/collect instead)."""
        A, p = len(act_idx), self.p
        n = disp.n
        t1 = time.perf_counter()
        self.stats.fused_dispatches += 1
        self.stats.fused_supersteps += n
        expand = disp.escape == "expand"
        if expand:
            self.stats.fused_escape_expand += 1
        elif disp.escape == "commit":
            self.stats.fused_escape_commit += 1
        else:
            self.stats.fused_ran_k += 1
        self.registry.counter(
            "service_fused_dispatches_total",
            "fused K-superstep device dispatches by escape reason",
            bucket=bucket_label(self.cfg), escape=disp.escape).inc()
        # pull device-resolved expansion states back into the host
        # tables: node ids are allocated contiguously, so rows
        # [size-at-dispatch-start, end) are exactly the entries the host
        # is missing.  An expansion escape excludes the escaped
        # superstep's insert (the host expansion path writes those).
        for r, g in zip(rows, act_idx):
            end = int(disp.size_pre[r] if expand else disp.sizes[r])
            lo = int(start_size[r])
            if end > lo:
                self.sts[g].write(np.arange(lo, end),
                                  disp.states[r, lo:end])
        # accounting for the device-complete supersteps.  The LAST
        # complete superstep of a normal exit goes through _commit_moves
        # exactly like the K=1 path (so move commits / evictions /
        # reroots replay bit-identically); an expansion escape instead
        # hands its partial superstep to the host expansion path below.
        carry = n if expand else n - 1
        for r, g in zip(rows, act_idx):
            slot = self.slots[g]
            slot.move_supersteps += carry
            slot.res.supersteps += carry
            slot.prev_size = int(disp.size_pre[r])
        self.stats.sim_rows += n * A * p
        self.stats.sim_batches += n
        self.stats.max_fused_rows = max(self.stats.max_fused_rows, A * p)
        if n:
            self._m_sim_rows.observe(A * p)
        if on_sub:
            # all n device-complete supersteps ran on the gathered sub-
            # arena (an escaped superstep counts itself in finish_superstep)
            self.stats.compacted_supersteps += n
            if not expand and not self.persistent_compaction:
                self._close_session()
        if expand:
            # complete the escaped superstep on host: the device already
            # applied selection (virtual loss, node_O) and insertion, so
            # the ordinary expand -> evaluate -> finish path picks up
            # exactly where begin_superstep would have handed off
            self.stats.supersteps += n
            self.stats.occupancy_sum += n * A / self.G
            self._m_supersteps.inc(n)
            sel = disp.sel_host
            hx = self.expander.expand(
                [(g, self.sts[g], {k: v[r] for k, v in sel.items()},
                  disp.new_nodes[r]) for r, g in zip(rows, act_idx)],
                tid=self._track)
            t2 = time.perf_counter()
            self.stats.t_expand += t2 - t1
            sim_states = np.concatenate([hx[g].sim_states for g in act_idx])
            pend = _PendingStep(
                ex=ex, ex_active=ex_active, rows=rows, act_idx=act_idx,
                sel_dev=disp.sel_dev, hx=hx, sim_states=sim_states,
                t_intree=t1 - t0, t_host=t2 - t1, tok=tok,
                compacted=on_sub)
            t3 = time.perf_counter()
            values, priors = self._sim_evaluate(sim_states)
            self.finish_superstep(pend, values, priors,
                                  t_sim=time.perf_counter() - t3)
            return n + 1
        self.stats.supersteps += n
        self.stats.occupancy_sum += n * A / self.G
        self.stats.t_intree += t1 - t0
        self._m_supersteps.inc(n)
        self._commit_moves(act_idx)
        if tok is not None:
            self.trace.end(tok)
        return n

    # ---- fused x overlap: double-buffered K-superstep dispatches ----
    def _fused_submit_gang(self, gang: int, active: np.ndarray,
                           K: int) -> dict:
        """Queue one gang's fused dispatch per owning shard WITHOUT any
        host read (executor run_supersteps_submit): the device programs
        run while the previous gang's collect/escape/accounting holds
        the main thread."""
        gmask = active & self.gangs.mask(gang)
        act_idx = np.flatnonzero(gmask)
        shards = getattr(self.exec, "shards", None) \
            or [(self.exec, 0, self.G)]
        parts = []
        for child, lo, n_run in shards:
            in_shard = (act_idx >= lo) & (act_idx < lo + n_run)
            if not in_shard.any():
                continue
            c_idx = act_idx[in_shard]
            c_rows = c_idx - lo
            c_active = np.zeros(child.G, bool)
            c_active[c_rows] = True
            budget_left, states, start_size = self._fused_upload(
                child, c_rows, c_idx)
            t0 = time.perf_counter()
            pend = child.run_supersteps_submit(
                c_active, self.p, K, self.env, self.sim, states,
                budget_left, self.alternating_signs)
            parts.append(dict(child=child, c_active=c_active, rows=c_rows,
                              act_idx=c_idx, start_size=start_size,
                              pend=pend, t0=t0))
        self.trace.instant("fused-stage", cat="phase",
                           tid=self._gang_track(gang), gang=gang, k=K,
                           slots=len(act_idx))
        return {"gang": gang, "parts": parts}

    def _fused_collect_gang(self, inf: dict) -> int:
        """Block on a staged gang's per-shard fused dispatches and run
        the ordinary accounting/escape body for each.  Returns the tick's
        superstep count (max over shards, as in the classic sharded
        path)."""
        ns = [0]
        for part in inf["parts"]:
            t_c0 = time.perf_counter()
            disp = part["child"].run_supersteps_collect(part["pend"])
            self._ov_wait_dev += time.perf_counter() - t_c0
            ns.append(self._fused_finish_one(
                part["child"], part["c_active"], part["rows"],
                part["act_idx"], disp, part["start_size"],
                on_sub=False, tok=None, t0=part["t0"]))
        return max(ns)

    def _fused_overlap_tick(self, K: int) -> int:
        """Overlap tick for K > 1: submit the next gang's fused programs,
        then collect + account the in-flight gang's — its host half runs
        while the freshly submitted programs execute on device."""
        if self._inflight is not None:   # mode switch: K rose above 1
            self.drain_overlap()
        self.stats.ticks += 1
        t_tick0 = time.perf_counter()
        tok = self.trace.begin("fused-dispatch", cat="phase",
                               tid=self._track, tick=self._now(), k=K,
                               overlap=True)
        self._admit()
        self._m_queue.set(len(self.queue))
        active = self._active()
        self._m_active.set(int(active.sum()))
        if not active.any():
            self.trace.end(tok)
            return 0
        self.last_decision = {
            "A": int(active.sum()), "G": self.G,
            "occupancy": float(active.sum()) / self.G, "compacted": False,
            "G_exec": self.G, "session": None,
        }
        if self._inflight_fused is None:   # warm-up
            self._inflight_fused = self._fused_submit_gang(
                self.gangs.next_gang(active), active, K)
        nxt = self.gangs.next_gang(active,
                                   exclude=self._inflight_fused["gang"])
        staged = None if nxt is None \
            else self._fused_submit_gang(nxt, active, K)
        inf, self._inflight_fused = self._inflight_fused, None
        n = self._fused_collect_gang(inf)
        self._inflight_fused = staged
        self.trace.end(tok)
        wall = time.perf_counter() - t_tick0
        self._ov_wall += wall
        if self._ov_wall > 0:
            self._m_busy_host.set(1.0 - self._ov_wait_host / self._ov_wall)
            self._m_busy_dev.set(1.0 - self._ov_wait_dev / self._ov_wall)
        return n

    # ---- move boundary: commit / advance / evict ----
    def _commit_moves(self, act_idx):
        sizes = self._sizes()
        best = None  # lazy: only computed when some slot finished its move
        for g in act_idx:
            slot = self.slots[g]
            slot.move_supersteps += 1
            slot.res.supersteps += 1
            size = int(sizes[g])
            done_move = (
                slot.move_supersteps >= slot.req.budget
                or size >= self.cfg.X
                or size == slot.prev_size  # saturated: no node inserted
            )
            slot.prev_size = size
            if not done_move:
                continue
            if best is None:
                best = self._best_actions()
            self._advance(g, int(best[g]))

    def _advance(self, g: int, a: int):
        slot, env = self.slots[g], self.env
        snap = self._slot_snapshot(g)
        # every path below rewrites or frees this slot on the full arena,
        # so a resident sub-arena spanning it must end now (its final
        # state was just scattered by the snapshot sync)
        self._invalidate_session(g)
        root = int(snap["root"])
        counts = np.array(snap["edge_N"][root][: slot.cfg.F], np.int64)
        new_state, reward, term = env.step(slot.root_state, a)
        slot.res.actions.append(a)
        slot.res.rewards.append(float(reward))
        slot.res.visit_counts.append(counts)
        slot.moves_done += 1
        last = bool(term) or slot.moves_done >= slot.req.moves
        self.trace.instant("move-commit", cat="request", tid=self._track,
                           uid=slot.req.uid, move=slot.moves_done - 1,
                           action=a, last=last)
        if self.move_listener is not None:
            self.move_listener(MoveEvent(
                uid=slot.req.uid, move_index=slot.moves_done - 1, action=a,
                reward=float(reward), visit_counts=counts, last=last))
        if last:
            slot.res.terminal = bool(term)
            if slot.req.keep_tree:
                slot.res.tree_snapshot = snap
            self._finish(slot.res)
            self.slots[g] = None
            return
        # long-lived request: next move on the same slot
        slot.root_state = new_state
        slot.move_supersteps = 0
        new_root = int(snap["child"][root, a])
        if self.reuse_subtree and new_root != NULL:
            arrays, old2new = reroot.reroot(self.cfg, snap, new_root)
            self.exec.write_slot(g, arrays)
            self.sts[g].compact(old2new)
            slot.prev_size = int(arrays["size"])
        else:  # paper-faithful full flush
            self.exec.reset_slot(g, max(env.num_actions(new_state), 1))
            self.sts[g].flush(new_state)
            slot.prev_size = 1

    def _finish(self, res: SearchResult):
        res.done_at = time.perf_counter()
        res.done_tick = self._now()
        self.completed.append(res)
        self.stats.completed += 1
        self._m_completed.inc()
        status = ("evicted" if res.deadline_evicted
                  else "cancelled" if res.cancelled else "done")
        self.trace.async_end("request", res.uid, cat="request",
                             tid=self._track, uid=res.uid, status=status,
                             moves=len(res.actions))
        if self.result_listener is not None:
            self.result_listener(res)

    # ---- drive to completion ----
    def run(self, max_supersteps: int = 100_000) -> list[SearchResult]:
        while (self.queue or self._active().any()) \
                and self.stats.supersteps < max_supersteps:
            if self.supersteps_per_dispatch > 1:
                if self.fused_dispatch() == 0:
                    break
            elif not self.superstep():
                break
        if self.overlap:   # budget exit can leave a gang in flight
            self.drain_overlap()
        return self.completed

    def close(self):
        """Flush any in-flight gang and resident session, and release
        expansion-engine resources (process pool, if any)."""
        if self.overlap and not self.retired:
            self.drain_overlap()
        self._close_session()
        if self._owns_expander:
            self.expander.close()
