"""ServiceFrontend — config-bucketed arena pools behind one submit().

The paper pins ONE tree shape per accelerator (the UCT banks are
synthesized for a fixed X/F/D); the serving analogue long carried the
same limit — one TreeConfig per SearchService (ROADMAP).  This frontend
removes it by routing instead of padding-away: each SearchRequest carries
its own TreeConfig, requests are bucketed by shape class
(core.tree.bucket_key — exact X and D, every scoring semantic, fanout
padded to the shared Fp lane width), and each bucket gets its own
ArenaPool with its own arena, executor program cache and StateTables.
Within a pool everything is the proven single-config machinery, so a
request's per-slot evolution is bit-identical to a dedicated
single-config SearchService run of it (tests/test_frontend.py pins this
across every executor).

Supersteps round-robin across pools: each frontend tick advances the
next pool that has work, so every bucket keeps its one-device-program-
per-phase batching while no bucket starves.  The host-expansion engine
is shared across pools (one process pool / one flattening path per
frontend, not per bucket).

Mirsoleimani et al.'s *Structured Parallel Programming for MCTS* argues
the scheduler, not the tree ops, should own the parallel structure —
here that split is literal: the frontend owns routing + interleaving,
the pools own the BSP supersteps, core.executor owns the device phases.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.expand import ExpansionEngine
from repro.core.mcts import Environment, SimulationBackend
from repro.core.tree import TreeConfig, bucket_key, canonical_config
from repro.service.pool import (
    ArenaPool, SearchRequest, SearchResult, ServiceStats,
)

__all__ = ["ServiceFrontend"]


class ServiceFrontend:
    """Multi-config MCTS serving frontend: one submit(), N arena pools.

    Pools are created lazily, one per request-config bucket, each with
    `G` slots and the frontend-wide executor / compaction / expansion
    settings.  `default_cfg` (optional) serves requests that carry no
    config of their own.
    """

    def __init__(
        self,
        env: Environment,
        sim: SimulationBackend,
        G: int,
        p: int,
        executor: str = "faithful",
        default_cfg: Optional[TreeConfig] = None,
        alternating_signs: bool = False,
        reuse_subtree: bool = True,
        compact_threshold: float = 0.0,
        compact_exit_threshold: Optional[float] = None,
        persistent_compaction: bool = True,
        expansion: str = "loop",
    ):
        self.env, self.sim = env, sim
        self.G, self.p = G, p
        self.executor = executor
        self.default_cfg = default_cfg
        self._pool_kw = dict(
            alternating_signs=alternating_signs,
            reuse_subtree=reuse_subtree,
            compact_threshold=compact_threshold,
            compact_exit_threshold=compact_exit_threshold,
            persistent_compaction=persistent_compaction,
        )
        # ONE host-expansion engine (and process pool, in "pool" mode)
        # shared by every bucket
        self.expander = ExpansionEngine(env, expansion)
        self.pools: dict[tuple, ArenaPool] = {}
        self._order: list[tuple] = []   # bucket keys in creation order
        self._rr = 0                    # round-robin cursor into _order
        self.last_key = None            # bucket of the latest superstep

    # ---- routing ----
    def _pool_for(self, cfg: TreeConfig) -> ArenaPool:
        key = bucket_key(cfg)
        pool = self.pools.get(key)
        if pool is None:
            pool = ArenaPool(
                canonical_config(cfg), self.env, self.sim, self.G, self.p,
                executor=self.executor, expander=self.expander,
                **self._pool_kw)
            self.pools[key] = pool
            self._order.append(key)
        return pool

    def submit(self, req: SearchRequest) -> ArenaPool:
        """Route a request to the ArenaPool serving its config bucket
        (created on first use).  Returns the pool, mostly for tests."""
        cfg = req.cfg if req.cfg is not None else self.default_cfg
        if cfg is None:
            raise ValueError(
                f"request uid={req.uid} carries no TreeConfig and the "
                f"frontend has no default_cfg")
        if req.cfg is None:
            req.cfg = cfg
        pool = self._pool_for(cfg)
        pool.submit(req)
        return pool

    # ---- round-robin superstep across buckets ----
    def superstep(self) -> bool:
        """Advance the next pool (round-robin) that has queued or active
        work by one BSP superstep.  False when every pool is drained."""
        n = len(self._order)
        for off in range(n):
            key = self._order[(self._rr + off) % n]
            pool = self.pools[key]
            if pool.has_work() and pool.superstep():
                self._rr = (self._rr + off + 1) % n
                self.last_key = key
                return True
        return False

    def run(self, max_supersteps: int = 100_000) -> list[SearchResult]:
        steps = 0
        while steps < max_supersteps and self.superstep():
            steps += 1
        return self.completed

    # ---- aggregate views ----
    @property
    def completed(self) -> list[SearchResult]:
        done: list[SearchResult] = []
        for key in self._order:
            done.extend(self.pools[key].completed)
        return done

    @property
    def stats(self) -> ServiceStats:
        """Frontend-wide aggregate of every pool's counters."""
        total = ServiceStats()
        for pool in self.pools.values():
            total = total.merge(pool.stats)
        return total

    def pool_summaries(self) -> list[dict]:
        """Per-bucket one-liners: shape class, load, session counters."""
        out = []
        for key in self._order:
            pool = self.pools[key]
            s = pool.stats
            out.append({
                "bucket": key, "cfg": pool.cfg, "G": pool.G,
                "queued": len(pool.queue),
                "active": int(np.sum(pool._active())),
                "supersteps": s.supersteps, "completed": s.completed,
                "session_gathers": s.session_gathers,
                "session_scatters": s.session_scatters,
                "session_reuses": s.session_reuses,
            })
        return out

    def close(self):
        for pool in self.pools.values():
            pool.close()          # flushes sessions; engine is shared
        self.expander.close()     # ... so the frontend closes it once
