"""ServiceFrontend — compatibility adapter over the SearchClient stack.

Historical surface: one submit() returning the routed ArenaPool, a
superstep()/run() drain loop, and aggregate stats/pool_summaries views.
Since the SearchClient redesign the frontend owns none of that logic —
it is a thin veneer over client.SearchClient / scheduler_core
.SchedulerCore, which carry the routing, the SchedulePolicy (round-robin
here by default, preserving the historical one-pool-per-tick cadence bit
for bit), deadline eviction, cold-pool retirement and the cross-pool
fused Simulation batch.  New code should hold SearchHandles from
SearchClient.submit instead of pools; this adapter exists so every
pre-redesign caller (tests, benches, examples) keeps working unchanged.

The layer map lives in service/client.py; the scheduling design in
service/scheduler_core.py.
"""

from __future__ import annotations

from typing import Optional, Union

from repro.core.mcts import Environment, SimulationBackend
from repro.core.tree import TreeConfig
from repro.service.client import SearchClient
from repro.service.pool import ArenaPool, SearchRequest, SearchResult
from repro.service.scheduler_core import SchedulePolicy

__all__ = ["ServiceFrontend"]


class ServiceFrontend:
    """Multi-config MCTS serving frontend: one submit(), N arena pools.

    Pools are created lazily, one per request-config bucket, each with
    `G` slots and the frontend-wide executor / compaction / expansion
    settings.  `default_cfg` (optional) serves requests that carry no
    config of their own.  `policy` / `retire_after_ticks` pass through to
    the scheduler core (round-robin and no retirement by default — the
    historical behavior).
    """

    def __init__(
        self,
        env: Environment,
        sim: SimulationBackend,
        G: int,
        p: int,
        executor: str = "faithful",
        default_cfg: Optional[TreeConfig] = None,
        alternating_signs: bool = False,
        reuse_subtree: bool = True,
        compact_threshold: float = 0.0,
        compact_exit_threshold: Optional[float] = None,
        persistent_compaction: bool = True,
        expansion: str = "loop",
        pool_workers: int = 2,
        supersteps_per_dispatch: int = 1,
        policy: Union[str, SchedulePolicy] = "round-robin",
        retire_after_ticks: Optional[int] = None,
        tracer=None,
        metrics=None,
        n_shards: int = 1,
        shard_devices: Optional[list] = None,
        overlap: bool = False,
        n_gangs: int = 2,
    ):
        self.client = SearchClient(
            env, sim, G=G, p=p, executor=executor, default_cfg=default_cfg,
            policy=policy, retire_after_ticks=retire_after_ticks,
            alternating_signs=alternating_signs, reuse_subtree=reuse_subtree,
            compact_threshold=compact_threshold,
            compact_exit_threshold=compact_exit_threshold,
            persistent_compaction=persistent_compaction,
            expansion=expansion, pool_workers=pool_workers,
            supersteps_per_dispatch=supersteps_per_dispatch,
            trace=tracer if tracer is not None else False,
            metrics=metrics if metrics is not None else False,
            n_shards=n_shards, shard_devices=shard_devices,
            overlap=overlap, n_gangs=n_gangs)
        self.core = self.client.core

    # ---- historical attribute surface (delegated) ----
    @property
    def env(self):
        return self.core.env

    @property
    def sim(self):
        return self.core.sim

    @property
    def G(self):
        return self.core.G

    @property
    def p(self):
        return self.core.p

    @property
    def executor(self):
        return self.core.executor

    @property
    def default_cfg(self):
        return self.core.default_cfg

    @property
    def expander(self):
        return self.core.expander

    @property
    def pools(self) -> dict:
        return self.core.pools

    @property
    def last_key(self):
        return self.core.last_key

    # ---- routing ----
    def submit(self, req: SearchRequest) -> ArenaPool:
        """Route a request to the ArenaPool serving its config bucket
        (created on first use).  Returns the pool for compatibility;
        callers that want a handle should use SearchClient.submit."""
        handle = self.client.submit(req)
        return self.core.pools[handle._key]

    # ---- scheduler ticks ----
    def superstep(self) -> bool:
        """One global scheduler tick (round-robin default: advance the
        next pool with work).  False when every pool is drained."""
        return self.core.tick()

    def run(self, max_supersteps: int = 100_000) -> list[SearchResult]:
        return self.core.run(max_supersteps)

    # ---- aggregate views ----
    @property
    def completed(self) -> list[SearchResult]:
        return self.core.completed

    @property
    def stats(self):
        """Frontend-wide aggregate of every pool's counters."""
        return self.core.stats

    def pool_summaries(self) -> list[dict]:
        """Per-bucket one-liners: shape class, load (via the public
        ArenaPool.load accessor), session counters."""
        return self.core.pool_summaries()

    def close(self):
        self.client.close()
