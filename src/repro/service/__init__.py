"""Multi-tree search service: vmapped tree arena + request scheduler.

See arena.py (G stacked UCTrees, one device program per phase) and
scheduler.py (slot admission / fused simulation batching / eviction).
"""

from repro.service.arena import (
    JaxArenaExecutor, PallasArenaExecutor, ReferenceArenaExecutor,
    make_arena_executor,
)
from repro.service.scheduler import (
    SearchRequest, SearchResult, SearchService, ServiceStats,
)

__all__ = [
    "JaxArenaExecutor", "PallasArenaExecutor", "ReferenceArenaExecutor",
    "make_arena_executor",
    "SearchRequest", "SearchResult", "SearchService", "ServiceStats",
]
