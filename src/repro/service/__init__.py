"""Multi-tree search service: config-bucketed arena pools + scheduler.

Three layers (see scheduler.py for the map): frontend.py routes
heterogeneous-config requests into per-bucket pools, pool.py owns one
bucket's arena and BSP superstep loop (with persistent compaction
sessions), and scheduler.py keeps SearchService — the single-bucket
compatibility surface — under its historical name.
"""

from repro.service.arena import (
    JaxArenaExecutor, PallasArenaExecutor, ReferenceArenaExecutor,
    make_arena_executor,
)
from repro.service.frontend import ServiceFrontend
from repro.service.pool import (
    ArenaPool, SearchRequest, SearchResult, ServiceStats,
)
from repro.service.scheduler import SearchService

__all__ = [
    "JaxArenaExecutor", "PallasArenaExecutor", "ReferenceArenaExecutor",
    "make_arena_executor",
    "ArenaPool", "SearchRequest", "SearchResult", "SearchService",
    "ServiceFrontend", "ServiceStats",
]
