"""Multi-tree search service: handles, global scheduler, arena pools.

The public API is client-first (new names exported first):

  SearchClient / SearchHandle    (client.py)   submit() -> opaque handle
      with done()/result()/cancel()/moves() streaming, poll()/run_until()
      progress — callers never touch pools or arenas.
  SchedulerCore / SchedulePolicy (scheduler_core.py)   global admission
      across config buckets (round-robin | weighted-queue-depth |
      deadline-aware), deadline eviction, cold-pool retirement, and the
      cross-pool fused SimulationBackend.evaluate batch.
  ArenaPool                      (pool.py)     one bucket's G-slot arena,
      StateTables, queue, and the BSP superstep body (split at the
      Simulation boundary for cross-pool fusion).

Compatibility adapters (deprecated surface, kept working):

  ServiceFrontend (frontend.py)  pre-handle multi-bucket frontend —
      submit() returns the routed pool; a thin veneer over SearchClient.
  SearchService   (scheduler.py) the single-bucket service under its
      historical name (one-time DeprecationWarning).
  arena-executor aliases         re-exported from core.executor; the
      repro.service.arena module itself is a lazy deprecation shim.
"""

from repro.service.client import SearchClient, SearchHandle
from repro.service.scheduler_core import (
    POLICY_NAMES, DeadlineAwarePolicy, RoundRobinPolicy, SchedulePolicy,
    SchedulerCore, WeightedQueueDepthPolicy, make_policy,
)
from repro.service.pool import (
    ArenaPool, MoveEvent, SearchRequest, SearchResult, ServiceStats,
)
from repro.service.frontend import ServiceFrontend
from repro.service.scheduler import SearchService
from repro.core.executor import (
    InTreeExecutor,
    JaxExecutor as JaxArenaExecutor,
    PallasExecutor as PallasArenaExecutor,
    ReferenceExecutor as ReferenceArenaExecutor,
    make_intree_executor as make_arena_executor,
)

__all__ = [
    # new serving API first
    "SearchClient", "SearchHandle",
    "SchedulerCore", "SchedulePolicy", "POLICY_NAMES", "make_policy",
    "RoundRobinPolicy", "WeightedQueueDepthPolicy", "DeadlineAwarePolicy",
    "ArenaPool", "MoveEvent", "SearchRequest", "SearchResult",
    "ServiceStats",
    # compatibility surface
    "ServiceFrontend", "SearchService",
    "InTreeExecutor", "JaxArenaExecutor", "PallasArenaExecutor",
    "ReferenceArenaExecutor", "make_arena_executor",
]
