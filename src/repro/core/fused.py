"""Fused K-superstep device dispatch — the serving loop without the
per-phase host round-trip.

The BSP superstep in repro.service.pool returns to Python between every
phase of every superstep (select → sel_to_host → insert → device_get →
host expand → finalize → backup); at small/medium G that dispatch
overhead, not kernel time, bounds throughput.  The paper's 35× in-tree
speedup comes from keeping tree state in SRAM and crossing the CPU/FPGA
boundary rarely — this module applies the same lesson to the XLA
dispatch boundary: ONE compiled ``lax.while_loop`` program runs

    select → insert → device expand (env twin) → device simulate →
    finalize → backup

for up to K supersteps, with the sim-state buffer device-resident for
the whole dispatch (fused rows cost zero H2D copies).  It escapes to the
host early only when

  * an expansion needs the env (``resolvable_device`` says no) — the
    loop exits **post-insert**, carrying the SelectionResult and the
    freshly assigned node ids so the host can complete that superstep
    through the ordinary ExpansionEngine path; everything the device
    already did (virtual loss, node_O, insert) equals the normal
    post-selection state, or
  * a move-commit boundary is hit (per-slot search budget exhausted,
    arena full, or a no-growth superstep) — the loop stops **after**
    the triggering superstep completes so the host can commit moves.

Bit-identity contract: supersteps are grouping-independent — every
phase inside the loop is the same jitted op the phase-by-phase path
calls, the env/sim device twins are bit-equal to their host twins (see
repro.envs.device), and escape points always coincide with the places
the K=1 path would have gone to host anyway.  tests/test_executor_matrix
enrolls fused runs against the sequential numpy oracle.

Requires ``not cfg.expand_all`` (prior-producing expansion keeps the
host path) and device twins on both env and sim backend (probes in
repro.envs.device).  The program is cached per
(cfg, variant, p, K, env, sim, alternating) — env/sim participate by
identity, so hold onto the same objects across dispatches.

Multi-device serving (core/sharded.py): the program itself is
placement-agnostic — jit dispatch follows the COMMITTED device of the
arena operand, so an executor whose trees were placed with
models.sharding.put_on_device runs its fused program on that device
with no code here changing.  The one cached program (per static key)
specializes per input sharding, which is how D shards share a compile
while each runs device-locally; ArenaPool.fused_dispatch drives one
call per shard.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fx
from repro.core import intree
from repro.core.tree import NULL, TreeConfig, UCTree

# escape reasons surfaced to the pool/scheduler accounting
ESC_RAN_K = 0    # ran all K supersteps, no boundary hit
ESC_COMMIT = 1   # a slot hit a move-commit boundary (stops after that
                 # superstep completes; host runs _commit_moves as usual)
ESC_EXPAND = 2   # an expansion was unresolvable on device (exits
                 # post-insert; host completes that superstep)

ESCAPE_NAMES = {ESC_RAN_K: "ran_k", ESC_COMMIT: "commit",
                ESC_EXPAND: "expand"}


@dataclasses.dataclass
class FusedDispatch:
    """Host-side result of one fused dispatch (all arrays numpy)."""

    n: int                      # complete supersteps executed on device
    escape: str                 # "ran_k" | "commit" | "expand"
    size_pre: np.ndarray        # [Ge] arena size before the most recent
                                # insert (== size after superstep n)
    sizes: np.ndarray           # [Ge] arena size after the dispatch
    states: np.ndarray          # [Ge, X, *S] the device ST buffer
    sel_dev: Optional[Any]      # device SelectionResult (escape=="expand")
    sel_host: Optional[dict]    # its host transfer
    new_nodes: Optional[np.ndarray]  # [Ge, p, Fp] (escape=="expand")


def _zero_sel(Ge: int, p: int, D: int) -> intree.SelectionResult:
    z = jnp.zeros((Ge, p), jnp.int32)
    zn = jnp.full((Ge, p, D), NULL, jnp.int32)
    return intree.SelectionResult(
        path_nodes=zn, path_actions=zn, depths=z, leaves=z,
        expand_action=jnp.full((Ge, p), NULL, jnp.int32),
        n_insert=z, insert_base=z)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5, 6))
def _fused_program(cfg: TreeConfig, variant: str, p: int, K: int,
                   env, sim, alternating: bool,
                   arena: UCTree, states, active, budget_left):
    """The compiled dispatch.  Static args make the cache key; arena,
    the ST buffer, the active mask [Ge] and per-slot remaining budgets
    [Ge] are traced."""
    if variant == "pallas":
        from repro.kernels import ops as kops  # lazy: core stays import-light

        select = lambda a: kops.select_arena(cfg, a, active, p)
        backup = lambda a, s, n, v: kops.backup_arena(
            cfg, a, active, s, n, v, alternating)
    else:
        select = lambda a: intree.select_arena(cfg, a, active, p, variant)
        backup = lambda a, s, n, v: intree.backup_arena(
            cfg, a, active, s, n, v, alternating)

    Ge = states.shape[0]
    state_tail = states.shape[2:]
    resolvable = getattr(env, "resolvable_device", None)

    def body(c):
        arena = c["arena"]
        size_pre = arena.size                       # [Ge] pre-insert sizes

        # -- Selection + Node Insertion (identical jitted phase ops) ----
        arena, sel = select(arena)
        arena, new_nodes = intree.insert_arena(cfg, arena, active, sel)

        # -- device expansion: resolve new nodes with the env twin ------
        leaves = sel.leaves                         # [Ge, p]
        leaf_states = jax.vmap(lambda st, lv: st[lv])(c["states"], leaves)
        ea = sel.expand_action
        expanding = (ea >= 0) & active[:, None]
        flat_states = leaf_states.reshape((Ge * p,) + state_tail)
        flat_a = jnp.maximum(ea, 0).reshape(-1)     # total fn: clamp masked
        if resolvable is None:
            esc_expand = jnp.asarray(False)
        else:
            ok = resolvable(flat_states, flat_a).reshape(Ge, p)
            esc_expand = jnp.any(expanding & ~ok)
        nxt, term = env.step_device(flat_states, flat_a)
        term = term.reshape(Ge, p)
        na = env.num_actions_device(nxt).astype(jnp.int32).reshape(Ge, p)
        nxt = nxt.reshape((Ge, p) + state_tail)
        nid = new_nodes[:, :, 0]                    # single-expand: lane 0
        wid = jnp.where(expanding, nid, cfg.X)      # out-of-range -> drop
        states2 = jax.vmap(
            lambda st, ids, rows: st.at[ids].set(rows, mode="drop")
        )(c["states"], wid, nxt)

        # -- Simulation on device (values only) -------------------------
        sim_nodes = jnp.where(expanding, nid, leaves)
        exp3 = expanding.reshape((Ge, p) + (1,) * len(state_tail))
        sim_states = jnp.where(exp3, nxt, leaf_states)
        vals = sim.evaluate_device(sim_states.reshape((Ge * p,) + state_tail))
        values_fx = fx.encode(vals, xp=jnp).reshape(Ge, p)

        # -- finalize + BackUp ------------------------------------------
        fin_nodes = jnp.where(expanding, nid, NULL)
        arena_fin = intree.finalize_arena(
            arena, fin_nodes, jnp.where(expanding, na, 0),
            jnp.where(expanding, term.astype(jnp.int32), 0),
            jnp.full((Ge, p), NULL, jnp.int32),
            jnp.zeros((Ge, p, cfg.Fp), jnp.int32))
        arena_done = backup(arena_fin, sel, sim_nodes, values_fx)

        # -- move-commit boundary (mirrors pool._commit_moves) ----------
        budget2 = c["budget_left"] - active.astype(jnp.int32)
        size_after = arena_done.size
        boundary = active & ((budget2 <= 0) | (size_after >= cfg.X)
                             | (size_after == size_pre))
        hit = jnp.any(boundary)

        done = dict(
            arena=arena_done, states=states2, n=c["n"] + 1,
            budget_left=budget2, size_pre=size_pre,
            stop=hit,
            esc=jnp.where(hit, jnp.int32(ESC_COMMIT), jnp.int32(ESC_RAN_K)),
            sel=c["sel"], new_nodes=c["new_nodes"])
        escaped = dict(
            arena=arena, states=c["states"], n=c["n"],
            budget_left=c["budget_left"], size_pre=size_pre,
            stop=jnp.asarray(True), esc=jnp.asarray(ESC_EXPAND, jnp.int32),
            sel=sel, new_nodes=new_nodes)
        return jax.tree.map(
            lambda e, d: jnp.where(esc_expand, e, d), escaped, done)

    c0 = dict(
        arena=arena, states=states, n=jnp.asarray(0, jnp.int32),
        budget_left=jnp.asarray(budget_left, jnp.int32),
        size_pre=arena.size, stop=jnp.asarray(False),
        esc=jnp.asarray(ESC_RAN_K, jnp.int32),
        sel=_zero_sel(Ge, p, cfg.D),
        new_nodes=jnp.full((Ge, p, cfg.Fp), NULL, jnp.int32))
    out = jax.lax.while_loop(
        lambda c: (~c["stop"]) & (c["n"] < K), body, c0)
    return (out["arena"], out["states"], out["n"], out["esc"],
            out["size_pre"], out["sel"], out["new_nodes"])


@dataclasses.dataclass
class PendingDispatch:
    """Device outputs of a queued fused program, NOT yet read to host.
    submit_supersteps returns one; collect_supersteps blocks on it and
    builds the FusedDispatch.  Everything here is a device array still in
    flight under JAX async dispatch — holding the handle costs nothing."""

    arena_size: Any      # [Ge] device sizes after the dispatch
    states_out: Any      # [Ge, X, *S] device ST buffer
    n: Any               # scalar: complete supersteps executed
    esc: Any             # scalar: escape code
    size_pre: Any        # [Ge] size before the most recent insert
    sel: Any             # device SelectionResult
    new_nodes: Any       # [Ge, p, Fp] device id block


def submit_supersteps(cfg: TreeConfig, variant: str, trees: UCTree,
                      active, p: int, K: int, env, sim, states,
                      budget_left, alternating: bool):
    """Queue up to K fused supersteps WITHOUT any host read.  Returns
    (new_trees, PendingDispatch) — the overlap mode stages one gang's
    dispatch here while another gang's host half runs, then redeems it
    with collect_supersteps."""
    arena, states_out, n, esc, size_pre, sel, new_nodes = _fused_program(
        cfg, variant, p, K, env, sim, bool(alternating),
        trees, jnp.asarray(states), jnp.asarray(active, bool),
        jnp.asarray(budget_left, jnp.int32))
    return arena, PendingDispatch(
        arena_size=arena.size, states_out=states_out, n=n, esc=esc,
        size_pre=size_pre, sel=sel, new_nodes=new_nodes)


def collect_supersteps(pend: PendingDispatch) -> FusedDispatch:
    """Blocking half: fetch the escape scalars and host views of a
    staged fused dispatch and build the FusedDispatch."""
    n = int(pend.n)
    esc = int(pend.esc)
    expand = esc == ESC_EXPAND
    disp = FusedDispatch(
        n=n, escape=ESCAPE_NAMES[esc],
        size_pre=np.asarray(jax.device_get(pend.size_pre)),
        sizes=np.asarray(jax.device_get(pend.arena_size)),
        states=np.asarray(jax.device_get(pend.states_out)),
        sel_dev=pend.sel if expand else None,
        sel_host=None, new_nodes=None)
    if expand:
        from repro.core.executor import _sel_to_host

        disp.sel_host = _sel_to_host(pend.sel)
        disp.new_nodes = np.asarray(jax.device_get(pend.new_nodes))
    return disp


def run_supersteps(cfg: TreeConfig, variant: str, trees: UCTree,
                   active, p: int, K: int, env, sim, states,
                   budget_left, alternating: bool):
    """Run up to K fused supersteps.  Returns (new_trees, FusedDispatch).

    ``states`` is the [Ge, X, *S] host ST image for the dispatched rows
    (uploaded once; new-node states come back in FusedDispatch.states —
    node ids are allocated contiguously, so the rows
    [size-at-dispatch-start, size_pre) are exactly the device-resolved
    expansions the host tables are missing).  Exactly
    collect_supersteps(submit_supersteps(...)) — the blocking wrapper
    over the overlap mode's split."""
    arena, pend = submit_supersteps(
        cfg, variant, trees, active, p, K, env, sim, states,
        budget_left, alternating)
    return arena, collect_supersteps(pend)
