"""State Table (paper §III-A) — host-resident environment-state store.

The ST is the second half of the paper's tree decomposition: a table of X
entries indexed by UCT node id, holding the application-specific
environment state (256 B for Pong, 432 B for Gomoku in the paper).  It
stays in host memory; only node indices cross the host<->accelerator link
(O(p) per superstep instead of O(p*gamma)).

Concurrency (paper §III-B): within a BSP superstep all writes target
*distinct, freshly allocated* node ids and no read depends on another
worker's write, so the table needs no synchronization.  Here that shows up
as plain vectorized numpy fancy-indexing — the invariant is asserted.
"""

from __future__ import annotations

import numpy as np


class StateTable:
    def __init__(self, capacity: int, state_shape: tuple, dtype=np.float32):
        self.capacity = capacity
        self.data = np.zeros((capacity,) + tuple(state_shape), dtype=dtype)
        self.valid = np.zeros(capacity, dtype=bool)
        # traffic accounting for the Fig. 4 analogue (ST ops on CPU)
        self.bytes_read = 0
        self.bytes_written = 0

    @property
    def state_bytes(self) -> int:
        return int(self.data[0].nbytes)

    def read(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx, dtype=np.int64)
        assert self.valid[idx].all(), "ST read of unwritten entry"
        self.bytes_read += int(idx.size) * self.state_bytes
        return self.data[idx]

    def write(self, idx: np.ndarray, states: np.ndarray):
        idx = np.asarray(idx, dtype=np.int64)
        assert np.unique(idx).size == idx.size, (
            "ST write collision — violates the paper's distinct-expansion invariant")
        self.data[idx] = states
        self.valid[idx] = True
        self.bytes_written += int(idx.size) * self.state_bytes

    def flush(self, new_root_state: np.ndarray):
        """Tree Flush (paper §IV-E): drop everything, entry 0 = new root."""
        self.valid[:] = False
        self.data[0] = new_root_state
        self.valid[0] = True
        self.bytes_written += self.state_bytes

    def compact(self, old2new: np.ndarray):
        """Subtree-reusing flush (core.reroot): relocate surviving entries
        to their new ids, invalidate the rest."""
        keep = np.flatnonzero(old2new >= 0)
        new_ids = old2new[keep]
        data = np.zeros_like(self.data)
        valid = np.zeros_like(self.valid)
        data[new_ids] = self.data[keep]
        valid[new_ids] = self.valid[keep]
        self.data, self.valid = data, valid
        self.bytes_written += int(len(keep)) * self.state_bytes

    def nbytes(self) -> int:
        return int(self.data.nbytes)
