"""Fixed-point edge-weight arithmetic (paper §IV-C).

The paper replaces HLS floating-point comparators (multi-cycle, loop-carried
dependency) with a fixed-point representation of the uct edge weight:
integer bits sized from the uct upper bound (V_max with N_s = X, N_hat = 1)
plus 16 fractional bits, giving single-cycle comparison with <0.01% loss on
the exploration term.

TPU adaptation: the VPU compares f32 natively, so single-cycle compare is
not the win here.  What fixed point *does* buy on TPU is

  1. bit-deterministic argmax across workers and across implementations
     (numpy oracle / jit jax / Pallas kernel) — integer compares have no
     rounding or reassociation hazards;
  2. exact, order-free virtual-loss and BackUp accumulation: integer adds
     commute exactly, so the vectorized scatter-add is bit-equal to the
     sequential CPU program, reproducing the paper's "exact same outputs
     as a CPU-only system" claim;
  3. halved VMEM footprint vs f64-safe accumulators.

Encoding: Qm.16 two's-complement int32 (m integer bits).  The helpers below
are used by the sequential numpy oracle, the batched jnp ops and the Pallas
kernels; keep them backend-generic (they accept numpy or jnp arrays).
"""

from __future__ import annotations

import numpy as np

FRAC_BITS = 16
FX_ONE = 1 << FRAC_BITS                  # 1.0 in Qm.16
FX_SCALE = float(FX_ONE)
FX_INV_SCALE = np.float32(1.0 / FX_ONE)

# Sentinels in the fixed-point score domain (int32).
FX_FORCE_EXPLORE = np.int32(1 << 28)     # "N_eff == 0" => +inf-like score;
                                         # leaves headroom for VL subtraction.
FX_NEG_INF = np.int32(-(1 << 30))        # invalid / unexpanded edge.
FX_MAX = np.int32((1 << 27) - 1)         # clamp bound for real scores so any
FX_MIN = np.int32(-(1 << 27))            # real score < FX_FORCE_EXPLORE.


def encode(x, xp=np):
    """f32 -> Qm.16 int32, round-to-nearest-even, clamped to the real-score
    band so encoded scores never collide with the sentinels."""
    fx = xp.round(xp.asarray(x, dtype=xp.float32) * xp.float32(FX_SCALE))
    fx = xp.clip(fx, xp.float32(FX_MIN), xp.float32(FX_MAX))
    return fx.astype(xp.int32)


def decode(fx, xp=np):
    """Qm.16 int32 -> f32."""
    return fx.astype(xp.float32) * FX_INV_SCALE


def encode_scalar(x: float) -> int:
    return int(encode(np.float32(x)))


def integer_bits_for(uct_upper_bound: float) -> int:
    """Paper §IV-C: integer bit-width assigned from the uct upper bound
    (V_max with N_s = X, N_hat = 1).  Returned for resource reporting
    (Table I analogue); the storage type here is always int32."""
    return max(1, int(np.ceil(np.log2(max(2.0, uct_upper_bound)))) + 1)


def uct_upper_bound(v_max: float, beta: float, x_nodes: int) -> float:
    """V_max + beta * sqrt(ln(X) / 1) — the paper's sizing rule."""
    return float(v_max) + float(beta) * float(np.sqrt(np.log(max(2, x_nodes))))


# --- order-preserving f32 <-> int32 bijection (beyond-paper utility) -----
#
# Monotone reinterpretation of IEEE-754 bits; used by tests to show the
# Qm.16 quantization (paper's choice) and exact bit-order encoding agree on
# argmax outcomes within the paper's claimed precision band.

def f32_to_ordered_i32(x, xp=np):
    bits = xp.asarray(x, dtype=xp.float32).view(xp.int32)
    # positive floats: identity (already monotone, >= 0);
    # negative floats: flip the 31 magnitude bits (more negative -> smaller).
    mask = xp.where(bits < 0, xp.int32(0x7FFFFFFF), xp.int32(0))
    return bits ^ mask


def ordered_i32_to_f32(i, xp=np):
    i = xp.asarray(i, dtype=xp.int32)
    mask = xp.where(i < 0, xp.int32(0x7FFFFFFF), xp.int32(0))
    return (i ^ mask).view(xp.float32)
