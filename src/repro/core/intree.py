"""Batched in-tree operations (the accelerator, paper §IV) in pure JAX.

This module is the jit'd TPU-native replacement of the paper's FPGA
in-tree-operation accelerator.  Three entry points mirror the accelerator's
three functions:

  select_batch   — Selection + virtual-loss apply for p workers
                   (paper: worker distributor + subtree pipelines);
  insert_batch   — Node Insertion (paper §IV-E);
  backup_batch   — BackUp from memoized paths (paper §IV-E memoization
                   buffer: Selection returns the traversed-edge refs so
                   BackUp never re-walks the tree).

Sequential-equivalence: the FPGA pipeline admits one worker per stage, so
worker k observes the virtual loss of workers < k — exactly the sequential
CPU program.  Here selection runs a `fori_loop` over workers (each a
masked D-step descent); every arithmetic step goes through the shared
fixed-point scoring spec, so outputs are bit-identical to
ref_sequential.py (tested).  Insertion and BackUp are *fully vectorized*:
their updates are integer scatter-adds, which commute exactly, so
vectorized == sequential — this is the TPU's win over the FPGA design,
which still serializes BackUp through pipeline stages.

A `relaxed=True` selection variant applies all virtual loss once per
superstep *after* all workers choose (single vectorized pass, no serial
chain).  This is a beyond-paper optimization: it trades the intra-superstep
worker-repulsion of WU-UCT for a ~p× shorter dependency chain; its effect
on search diversity is measured in benchmarks/bench_diversity.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fx
from repro.core import scoring
from repro.core.tree import NULL, TreeConfig, UCTree, where_trees


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SelectionResult:
    path_nodes: Any     # [p, D] i32, NULL-padded
    path_actions: Any   # [p, D] i32
    depths: Any         # [p] i32
    leaves: Any         # [p] i32
    expand_action: Any  # [p] i32: action, NULL, or -2 (expand-all claim)
    n_insert: Any       # [p] i32
    insert_base: Any    # [p] i32: first node id this worker will insert


def _scores_at(cfg: TreeConfig, tree: UCTree, node, edge_VL, node_O):
    return scoring.edge_scores_fx(
        cfg,
        child=tree.child[node],
        edge_N=tree.edge_N[node],
        edge_W=tree.edge_W[node],
        edge_VL=edge_VL[node],
        edge_P=tree.edge_P[node],
        node_N=tree.node_N[node][None],
        node_O=node_O[node][None],
        num_actions=tree.num_actions[node][None],
        log_table=tree.log_table,
        xp=jnp,
    )


@functools.partial(jax.jit, static_argnums=(0, 2, 3))
def select_batch(cfg: TreeConfig, tree: UCTree, p: int, relaxed: bool = False):
    """Selection for p workers.  Returns (tree', SelectionResult)."""
    D = cfg.D
    i32 = jnp.int32

    def descend(j, carry):
        edge_VL, node_O, pn, pa, depths, leaves = carry
        if not relaxed:
            node_O = node_O.at[tree.root].add(1)

        def level(d, st):
            node, depth, edge_VL, node_O, pn, pa = st
            leaf = scoring.is_leaf(
                cfg,
                num_expanded=tree.num_expanded[node],
                num_actions=tree.num_actions[node],
                terminal=tree.terminal[node],
                depth=depth,
                xp=jnp,
            )
            active = (~leaf) & (d == depth)
            s = _scores_at(cfg, tree, node, edge_VL, node_O)
            a = scoring.argmax_first(s, xp=jnp)
            inc = jnp.where(active, i32(1), i32(0))
            if not relaxed:
                edge_VL = edge_VL.at[node, a].add(inc)
            nxt = tree.child[node, a]
            pn = pn.at[j, d].set(jnp.where(active, node, pn[j, d]))
            pa = pa.at[j, d].set(jnp.where(active, a, pa[j, d]))
            node = jnp.where(active, nxt, node)
            if not relaxed:
                node_O = node_O.at[node].add(inc)
            depth = depth + inc
            return node, depth, edge_VL, node_O, pn, pa

        node, depth, edge_VL, node_O, pn, pa = jax.lax.fori_loop(
            0, D, level, (tree.root, i32(0), edge_VL, node_O, pn, pa)
        )
        depths = depths.at[j].set(depth)
        leaves = leaves.at[j].set(node)
        return edge_VL, node_O, pn, pa, depths, leaves

    pn = jnp.full((p, D), NULL, dtype=i32)
    pa = jnp.full((p, D), NULL, dtype=i32)
    depths = jnp.zeros(p, dtype=i32)
    leaves = jnp.zeros(p, dtype=i32)
    edge_VL, node_O = tree.edge_VL, tree.node_O
    edge_VL, node_O, pn, pa, depths, leaves = jax.lax.fori_loop(
        0, p, descend, (edge_VL, node_O, pn, pa, depths, leaves)
    )
    if relaxed:
        # Beyond-paper: one-shot VL/O application after all choices; scores
        # above read only the pre-superstep statistics (no serial chain).
        X = tree.X
        idx_n = jnp.where(pn != NULL, pn, X)
        edge_VL = edge_VL.at[idx_n, pa].add(1, mode="drop")
        node_O = node_O.at[idx_n].add(1, mode="drop")
        node_O = node_O.at[leaves].add(1)

    tree = dataclasses.replace(tree, edge_VL=edge_VL, node_O=node_O)
    return _assign_expansions(cfg, tree, pn, pa, depths, leaves, p)


def _segment_rank(keys, p):
    """r[j] = #{i < j : keys[i] == keys[j]} — stable within-group rank."""
    i32 = jnp.int32
    sidx = jnp.argsort(keys, stable=True)
    sk = keys[sidx]
    pos = jnp.arange(p, dtype=i32)
    new_run = jnp.concatenate([jnp.array([True]), sk[1:] != sk[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum, jnp.where(new_run, pos, i32(0)))
    r_sorted = pos - run_start
    return jnp.zeros(p, dtype=i32).at[sidx].set(r_sorted)


@functools.partial(jax.jit, static_argnums=(0, 2))
def select_batch_wavefront(cfg: TreeConfig, tree: UCTree, p: int):
    """Beyond-paper selection: level-synchronous wavefront with rank-based
    repulsion.

    The faithful path serializes workers (chain length p*D) to reproduce
    the FPGA pipeline's virtual-loss ordering.  Here all p workers advance
    one level per step (chain length D).  Workers that meet at the same
    node are spread across that node's top-scoring edges by their stable
    within-group rank — a deterministic, vectorized surrogate for the
    repulsion virtual loss provides across a superstep.  Virtual loss / O
    counters are applied once at the end (cross-superstep semantics are
    preserved exactly; intra-superstep repulsion is rank-based instead of
    VL-based).  Diversity impact vs the faithful path is measured in
    benchmarks (bench_diversity).
    """
    D, Fp, X = cfg.D, cfg.Fp, tree.X
    i32 = jnp.int32
    w = jnp.arange(p, dtype=i32)

    def level(d, st):
        nodes, depth, pn, pa = st
        leaf = scoring.is_leaf(
            cfg,
            num_expanded=tree.num_expanded[nodes],
            num_actions=tree.num_actions[nodes],
            terminal=tree.terminal[nodes],
            depth=depth,
            xp=jnp,
        )
        active = (~leaf) & (depth == d)
        s = scoring.edge_scores_fx(
            cfg,
            child=tree.child[nodes],
            edge_N=tree.edge_N[nodes],
            edge_W=tree.edge_W[nodes],
            edge_VL=tree.edge_VL[nodes],
            edge_P=tree.edge_P[nodes],
            node_N=tree.node_N[nodes][:, None],
            node_O=tree.node_O[nodes][:, None],
            num_actions=tree.num_actions[nodes][:, None],
            log_table=tree.log_table,
            xp=jnp,
        )                                                   # [p, Fp]
        order = jnp.argsort(-s, axis=-1, stable=True)       # best-first, ties by lane
        n_valid = jnp.maximum(jnp.sum(s > fx.FX_NEG_INF, axis=-1), 1).astype(i32)
        rank = _segment_rank(jnp.where(active, nodes, X + w), p)
        a = jnp.take_along_axis(
            order, (rank % n_valid)[:, None], axis=-1)[:, 0].astype(i32)
        pn = pn.at[w, d].set(jnp.where(active, nodes, pn[:, d]))
        pa = pa.at[w, d].set(jnp.where(active, a, pa[:, d]))
        nodes = jnp.where(active, tree.child[nodes, a], nodes)
        depth = depth + jnp.where(active, i32(1), i32(0))
        return nodes, depth, pn, pa

    pn = jnp.full((p, D), NULL, dtype=i32)
    pa = jnp.full((p, D), NULL, dtype=i32)
    nodes, depths, pn, pa = jax.lax.fori_loop(
        0, D, level, (jnp.broadcast_to(tree.root, (p,)), jnp.zeros(p, i32), pn, pa)
    )
    leaves = nodes
    idx_n = jnp.where(pn != NULL, pn, X)
    edge_VL = tree.edge_VL.at[idx_n, pa].add(1, mode="drop")
    node_O = tree.node_O.at[idx_n].add(1, mode="drop").at[leaves].add(1)
    tree = dataclasses.replace(tree, edge_VL=edge_VL, node_O=node_O)
    return _assign_expansions(cfg, tree, pn, pa, depths, leaves, p)


def _assign_expansions(cfg, tree, pn, pa, depths, leaves, p):
    """BSP expansion-assignment post-pass (worker order), shared by all
    selection variants."""
    i32 = jnp.int32

    def assign(j, carry):
        pending, claimed, budget, ea, ni = carry
        leaf = leaves[j]
        can = (tree.terminal[leaf] == 0) & (depths[j] < cfg.D)
        if cfg.expand_all:
            k = tree.num_actions[leaf]
            ok = (
                can
                & (claimed[leaf] == 0)
                & (tree.num_expanded[leaf] == 0)
                & (k > 0)
                & (budget >= k)
            )
            ea = ea.at[j].set(jnp.where(ok, i32(-2), i32(NULL)))
            ni = ni.at[j].set(jnp.where(ok, k, i32(0)))
            claimed = claimed.at[leaf].max(jnp.where(ok, i32(1), i32(0)))
            budget = budget - jnp.where(ok, k, i32(0))
        else:
            a = tree.num_expanded[leaf] + pending[leaf]
            ok = can & (a < tree.num_actions[leaf]) & (budget >= 1)
            ea = ea.at[j].set(jnp.where(ok, a, i32(NULL)))
            ni = ni.at[j].set(jnp.where(ok, i32(1), i32(0)))
            pending = pending.at[leaf].add(jnp.where(ok, i32(1), i32(0)))
            budget = budget - jnp.where(ok, i32(1), i32(0))
        return pending, claimed, budget, ea, ni

    pending = jnp.zeros(tree.X, dtype=i32)
    claimed = jnp.zeros(tree.X, dtype=i32)
    ea = jnp.full(p, NULL, dtype=i32)
    ni = jnp.zeros(p, dtype=i32)
    budget0 = jnp.asarray(cfg.X, i32) - tree.size
    _, _, _, ea, ni = jax.lax.fori_loop(
        0, p, assign, (pending, claimed, budget0, ea, ni)
    )
    # dtype pinned: cumsum of i32 widens to i64 under JAX_ENABLE_X64
    insert_base = tree.size + jnp.cumsum(ni, dtype=i32) - ni
    return tree, SelectionResult(pn, pa, depths, leaves, ea, ni, insert_base)


@functools.partial(jax.jit, static_argnums=(0,))
def insert_batch(cfg: TreeConfig, tree: UCTree, sel: SelectionResult):
    """Node Insertion for all workers at once (vectorized scatter).

    Returns (tree', new_nodes[p, Fp] NULL-padded).  Distinctness of target
    edges is guaranteed by the assignment post-pass (the paper's
    'all workers expand different nodes' invariant), so scatters never
    collide except the commutative num_expanded counts.
    """
    p = sel.leaves.shape[0]
    X, Fp = tree.X, tree.Fp
    i32 = jnp.int32
    lane = jnp.arange(Fp, dtype=i32)[None, :]                     # [1, Fp]
    single = (sel.expand_action[:, None] >= 0)                    # [p, 1]
    allmode = (sel.expand_action[:, None] == -2)                  # [p, 1]
    act = jnp.where(single, sel.expand_action[:, None], lane)     # [p, Fp]
    valid = (single & (lane == 0)) | (allmode & (lane < sel.n_insert[:, None]))
    nid = sel.insert_base[:, None] + jnp.where(single, 0, lane)   # [p, Fp]
    leaf = jnp.broadcast_to(sel.leaves[:, None], (p, Fp))

    li = jnp.where(valid, leaf, X)
    ai = jnp.where(valid, act, Fp)
    ci = jnp.where(valid, nid, X)
    child = tree.child.at[li, ai].set(jnp.where(valid, nid, NULL), mode="drop")
    node_depth = tree.node_depth.at[ci].set(
        tree.node_depth[sel.leaves][:, None] + 1, mode="drop")
    num_actions = tree.num_actions.at[ci].set(i32(cfg.F), mode="drop")
    num_expanded = tree.num_expanded.at[jnp.where(valid, leaf, X)].add(
        jnp.where(valid, i32(1), i32(0)), mode="drop")
    size = tree.size + jnp.sum(sel.n_insert, dtype=i32)
    new_nodes = jnp.where(valid, nid, NULL)
    tree = dataclasses.replace(
        tree, child=child, node_depth=node_depth,
        num_actions=num_actions, num_expanded=num_expanded, size=size)
    return tree, new_nodes


@jax.jit
def finalize_expansion_batch(
    tree: UCTree,
    nodes,          # [k] i32 (NULL-padded ok)
    num_actions,    # [k] i32
    terminal,       # [k] i32
    prior_parent=None,   # [k2] i32 parent ids (NULL-padded ok)
    priors_fx=None,      # [k2, Fp] i32
):
    X = tree.X
    idx = jnp.where(nodes == NULL, X, nodes)
    na = tree.num_actions.at[idx].set(num_actions, mode="drop")
    tm = tree.terminal.at[idx].set(terminal, mode="drop")
    ep = tree.edge_P
    if priors_fx is not None:
        pidx = jnp.where(prior_parent == NULL, X, prior_parent)
        ep = ep.at[pidx].set(priors_fx, mode="drop")
    return dataclasses.replace(tree, num_actions=na, terminal=tm, edge_P=ep)


@functools.partial(jax.jit, static_argnums=(0, 5, 6))
def backup_batch(
    cfg: TreeConfig,
    tree: UCTree,
    sel: SelectionResult,
    sim_nodes,      # [p] i32
    values_fx,      # [p] i32 Qm.16
    alternating_signs: bool = False,
    with_mask: bool = False,
    dropped=None,   # [p] bool — straggler/failed workers (recover-only)
):
    """BackUp for all p workers — one vectorized scatter-add pass.

    Exact integer arithmetic makes the scatter order-free, so this equals
    the sequential program bit-for-bit while touching each path edge once.

    Fault tolerance (distributed.fault.BSPFaultPolicy): a `dropped` worker
    gets a VL-recovery-only backup — its virtual loss and in-flight
    counters are removed exactly as if it had never been dispatched, but
    it contributes no visit counts or reward.  The UCT quiescence
    invariants (VL == 0, O == 0) therefore survive worker loss.
    """
    p, D = sel.path_nodes.shape
    X = tree.X
    i32 = jnp.int32
    on_path = sel.path_nodes != NULL                              # [p, D]
    expanded = (sel.expand_action >= 0) & jnp.asarray(not cfg.expand_all)
    sim_depth = sel.depths + jnp.where(expanded, 1, 0)            # [p]

    if with_mask:
        alive = ~jnp.asarray(dropped)
    else:
        alive = jnp.ones((p,), bool)

    d_idx = jnp.arange(D, dtype=i32)[None, :]
    if alternating_signs:
        sign = jnp.where((sim_depth[:, None] - d_idx) % 2 == 1, i32(-1), i32(1))
    else:
        sign = jnp.ones((p, D), dtype=i32)

    rinc = jnp.where(on_path, i32(1), i32(0))                 # recovery
    ninc = rinc * jnp.where(alive, i32(1), i32(0))[:, None]   # accumulation
    winc = ninc * sign * values_fx[:, None]
    li = jnp.where(on_path, sel.path_nodes, X)
    ai = jnp.where(on_path, sel.path_actions, tree.Fp)

    edge_N = tree.edge_N.at[li, ai].add(ninc, mode="drop")
    edge_W = tree.edge_W.at[li, ai].add(winc, mode="drop")
    edge_VL = tree.edge_VL.at[li, ai].add(-rinc, mode="drop")
    node_N = tree.node_N.at[li].add(ninc, mode="drop")
    node_O = tree.node_O.at[li].add(-rinc, mode="drop")
    node_N = node_N.at[sel.leaves].add(jnp.where(alive, i32(1), i32(0)))
    node_O = node_O.at[sel.leaves].add(-1)

    # Expansion edges (single-expand mode): seed the sim node's in-edge.
    live_exp = expanded & alive
    e_leaf = jnp.where(live_exp, sel.leaves, X)
    e_act = jnp.where(live_exp, sel.expand_action, tree.Fp)
    e_sign = jnp.where(
        jnp.asarray(alternating_signs) & ((sim_depth - sel.depths) % 2 == 1),
        i32(-1), i32(1))
    e_inc = jnp.where(live_exp, i32(1), i32(0))
    edge_N = edge_N.at[e_leaf, e_act].add(e_inc, mode="drop")
    edge_W = edge_W.at[e_leaf, e_act].add(e_inc * e_sign * values_fx, mode="drop")
    node_N = node_N.at[jnp.where(live_exp, sim_nodes, X)].add(1, mode="drop")

    return dataclasses.replace(
        tree, edge_N=edge_N, edge_W=edge_W, edge_VL=edge_VL,
        node_N=node_N, node_O=node_O)


@jax.jit
def best_root_action(tree: UCTree):
    """Robust-child action choice at the MCTS step boundary."""
    Fp = tree.Fp
    lane = jnp.arange(Fp, dtype=jnp.int32)
    n = tree.edge_N[tree.root]
    ok = (lane < tree.num_actions[tree.root]) & (tree.child[tree.root] != NULL)
    return jnp.argmax(jnp.where(ok, n, -1)).astype(jnp.int32)


# --------------------------------------------------------------------------
# Arena entry points (service layer): every op vmapped over G stacked trees
# --------------------------------------------------------------------------
#
# The arena (tree.init_arena / stack_trees) carries G independent searches
# in one pytree; these wrappers run the single-tree ops above on every slot
# in ONE device program.  `active` is a [G] bool mask: the op still executes
# on idle slots (a uniform program, no ragged dispatch) but where_trees
# discards their tree updates, so an idle slot's statistics are untouched
# and its SelectionResult rows are dead data the host must ignore.
#
# Per-slot semantics are exactly the single-tree semantics — vmap adds a
# batch axis without changing any per-element arithmetic — so the arena
# inherits the reference-executor bit-compatibility of select/insert/backup
# (asserted end-to-end in tests/test_service.py).  The Pallas kernels have
# their own arena entry points (kernels.ops.select_arena/backup_arena, a
# [G]-grid launch instead of vmap) behind the same executor contract.

@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def select_arena(cfg: TreeConfig, arena: UCTree, active, p: int,
                 variant: str = "faithful"):
    """Selection for p workers on every slot.  Returns (arena', sel[G,...])."""
    if variant == "wavefront":
        fn = lambda t: select_batch_wavefront(cfg, t, p)
    else:
        fn = lambda t: select_batch(cfg, t, p, variant == "relaxed")
    new, sel = jax.vmap(fn)(arena)
    return where_trees(active, new, arena), sel


@functools.partial(jax.jit, static_argnums=(0,))
def insert_arena(cfg: TreeConfig, arena: UCTree, active, sel):
    """Node Insertion on every slot.  Returns (arena', new_nodes[G, p, Fp])."""
    new, nodes = jax.vmap(lambda t, s: insert_batch(cfg, t, s))(arena, sel)
    return where_trees(active, new, arena), nodes


@jax.jit
def finalize_arena(arena: UCTree, nodes, num_actions, terminal,
                   prior_parent, priors_fx):
    """finalize_expansion_batch per slot.  All inputs carry a leading [G]
    axis; idle/short slots are NULL-padded rows (finalize is NULL-safe), so
    no active mask is needed."""
    return jax.vmap(finalize_expansion_batch)(
        arena, nodes, num_actions, terminal, prior_parent, priors_fx)


@functools.partial(jax.jit, static_argnums=(0, 6, 7))
def backup_arena(cfg: TreeConfig, arena: UCTree, active, sel, sim_nodes,
                 values_fx, alternating_signs: bool = False,
                 with_mask: bool = False, dropped=None):
    """BackUp on every slot ([G, p] sim nodes / values).  With
    `with_mask`, `dropped` is a [G, p] straggler mask: dropped workers get
    the VL-recovery-only backup of backup_batch."""
    if with_mask:
        new = jax.vmap(
            lambda t, s, n, v, d: backup_batch(
                cfg, t, s, n, v, alternating_signs, True, d)
        )(arena, sel, sim_nodes, values_fx, jnp.asarray(dropped))
    else:
        new = jax.vmap(
            lambda t, s, n, v: backup_batch(cfg, t, s, n, v, alternating_signs)
        )(arena, sel, sim_nodes, values_fx)
    return where_trees(active, new, arena)


@jax.jit
def best_root_action_arena(arena: UCTree):
    """Robust-child action for every slot.  Returns [G] i32."""
    return jax.vmap(best_root_action)(arena)
