"""Tree-Parallel MCTS BSP driver (paper Alg. 2 / Fig. 2).

One superstep =
  1. Selection + Node Insertion on the accelerator          (device)
  2. Receive buffer: node indices s, s' -> host              (O(p) transfer)
  3. ST reads, 1-step simulations, ST writes                 (host, sync-free)
  4. Simulation phase (software rollout or NN/LM inference)  (host/device)
  5. barrier; Send buffer: rewards -> accelerator            (O(p) transfer)
  6. BackUp on the accelerator                               (device)

The driver is executor-agnostic: the in-tree operations run on the
sequential numpy reference (the paper's CPU-only baseline), the batched
jit ops, the arena-native Pallas kernels, or the beyond-paper wavefront
variant — selected by name through the unified executor stack
(core.executor), of which this driver is the G=1 client (the service
scheduler is the G>1 client of the very same dispatch).  All executors
are bit-compatible with the reference except "wavefront"/"relaxed"
(documented intra-superstep semantics change).

Phase wall-times are recorded per superstep so the benchmark harness can
reproduce the paper's Fig. 4 (in-tree latency) and Fig. 5 (system
throughput + breakdown) directly from driver telemetry.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Protocol

import numpy as np

from repro.core import fixedpoint as fx
from repro.core.executor import (
    InTreeExecutor, ReferenceExecutor, make_intree_executor,
)
from repro.core.expand import (  # noqa: F401  (re-export: long-standing home)
    ExpansionEngine, HostExpansion, encode_prior_rows, host_expand_phase,
)
from repro.core.state_table import StateTable
from repro.core.tree import NULL, TreeConfig, UCTree


# --------------------------------------------------------------------------
# Environment / simulation-backend interfaces
# --------------------------------------------------------------------------

class Environment(Protocol):
    """Host-side environment.  States are fixed-shape numpy arrays so they
    can live in the ST.  Action index `a` at a node means "the a-th legal
    action of that node's state" (stable per state)."""

    state_shape: tuple
    state_dtype: Any
    max_actions: int

    def initial_state(self, seed: int) -> np.ndarray: ...
    def num_actions(self, state: np.ndarray) -> int: ...
    def step(self, state: np.ndarray, a: int) -> tuple[np.ndarray, float, bool]: ...


class SimulationBackend(Protocol):
    """Maps a batch of states to values (and optionally priors).  This is
    the paper's Simulation phase: software rollout (Pong) or DNN inference
    (Gomoku).  The LM zoo plugs in here via LMSimBackend."""

    def evaluate(self, states: np.ndarray) -> tuple[np.ndarray, np.ndarray | None]: ...


class RolloutBackend:
    """Software simulation until termination (paper's OpenAI-gym path)."""

    def __init__(self, env, max_steps: int = 200, seed: int = 0, discount: float = 1.0):
        self.env, self.max_steps, self.discount = env, max_steps, discount
        self.rng = np.random.RandomState(seed)

    def evaluate(self, states):
        vals = np.zeros(len(states), dtype=np.float32)
        for i, s in enumerate(states):
            v, g, cur = 0.0, 1.0, s
            for _ in range(self.max_steps):
                k = self.env.num_actions(cur)
                if k == 0:
                    break
                cur, r, term = self.env.step(cur, int(self.rng.randint(k)))
                v += g * r
                g *= self.discount
                if term:
                    break
            vals[i] = v
        return vals, None


# --------------------------------------------------------------------------
# In-tree executors — the unified stack lives in core.executor; re-exported
# here for the long-standing import surface (repro.core.make_executor etc.)
# --------------------------------------------------------------------------

def make_executor(cfg: TreeConfig, name: str) -> InTreeExecutor:
    """Single-tree executor: the G=1 instance of the unified stack."""
    return make_intree_executor(cfg, 1, name)


# --------------------------------------------------------------------------
# Host expansion phase — lives in core.expand (HostExpansion /
# host_expand_phase / ExpansionEngine are re-exported above: the engine is
# shared by this G=1 driver and service/scheduler.py, which batches every
# slot's pending expansions into one VectorEnv.step_batch call)
# --------------------------------------------------------------------------

# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StepStats:
    supersteps: int = 0
    sim_requests: int = 0
    t_select: float = 0.0
    t_insert: float = 0.0
    t_backup: float = 0.0
    t_transfer: float = 0.0
    t_st: float = 0.0
    t_sim: float = 0.0

    @property
    def t_intree(self) -> float:
        # Paper Fig. 4 metric: Selection + Expansion(tree half) + BackUp
        # + host<->accel transfer + ST operations.
        return self.t_select + self.t_insert + self.t_backup + self.t_transfer + self.t_st

    @property
    def t_total(self) -> float:
        return self.t_intree + self.t_sim


class TreeParallelMCTS:
    """The full system of Fig. 2 on one host — the G=1 client of the
    unified executor stack (`m.tree` views slot 0 of the executor's
    arena; assigning it writes the slot back)."""

    def __init__(
        self,
        cfg: TreeConfig,
        env: Environment,
        sim: SimulationBackend,
        p: int,
        executor: str = "faithful",
        alternating_signs: bool = False,
        seed: int = 0,
        expansion: str = "loop",
    ):
        self.cfg, self.env, self.sim, self.p = cfg, env, sim, p
        self.alternating_signs = alternating_signs
        self.exec = make_intree_executor(cfg, 1, executor)
        self.expander = ExpansionEngine(env, expansion)
        self.st = StateTable(cfg.X, env.state_shape, env.state_dtype)
        # fixed finalize width (the arena finalize takes one shape per slot)
        self.K = p * cfg.Fp if cfg.expand_all else p
        self.reset(seed)

    @property
    def tree(self):
        return self.exec.get_tree(0)

    @tree.setter
    def tree(self, t):
        self.exec.set_tree(t, 0)

    def reset(self, seed: int = 0):
        s0 = self.env.initial_state(seed)
        self.tree = self.exec.init(self.env.num_actions(s0))
        self.st.flush(s0)
        self.root_state = s0
        self.stats = StepStats()

    # -- one BSP superstep (Alg. 2) ------------------------------------
    def superstep(self, fault_injector=None):
        """One BSP superstep.  `fault_injector(p) -> done[p] bool` models
        simulation workers that miss the barrier (stragglers/failures);
        with a BSPFaultPolicy-style mask, missing workers get a
        VL-recovery-only backup (see intree.backup_batch) so the tree
        invariants survive worker loss."""
        cfg, p, st = self.cfg, self.p, self.st
        active = np.ones(1, bool)
        t0 = time.perf_counter()
        sel_dev = self.exec.selection(active, p)
        self.exec.block()
        t1 = time.perf_counter()
        sel = self.exec.sel_to_host(sel_dev)           # [1, p, ...]
        slot_sel = {k: v[0] for k, v in sel.items()}
        t2 = time.perf_counter()

        # Node Insertion (tree half, accelerator)
        new_nodes = self.exec.insert(active, sel_dev)  # [1, p, Fp] numpy
        t3 = time.perf_counter()

        # --- host: ST reads + 1-step sims + ST writes (sync-free) ---
        t4 = time.perf_counter()
        hx = self.expander.expand([(0, st, slot_sel, new_nodes[0])])[0]
        sim_nodes = hx.sim_nodes
        t5 = time.perf_counter()

        # --- Simulation phase ---
        values, priors = self.sim.evaluate(hx.sim_states)
        t6 = time.perf_counter()

        # --- barrier; Send buffer -> accelerator; finalize + BackUp ---
        if hx.fin_nodes:   # saturated/terminal supersteps insert nothing
            nodes, na, term, pp, pf = hx.padded_finalize_args(
                self.K, p, cfg.Fp, priors)
            self.exec.finalize(nodes[None], na[None], term[None], pp[None],
                               pf[None])
        values_fx = np.asarray(fx.encode(values), np.int32)
        dropped = None
        if fault_injector is not None:
            done = np.asarray(fault_injector(p), bool)
            dropped = ~done
            if not dropped.any():
                dropped = None
        t7 = time.perf_counter()
        self.exec.backup(
            active, sel_dev, sim_nodes[None].astype(np.int32),
            values_fx[None], self.alternating_signs,
            None if dropped is None else dropped[None])
        self.exec.block()
        t8 = time.perf_counter()

        s = self.stats
        s.supersteps += 1
        s.sim_requests += p
        s.t_select += t1 - t0
        s.t_transfer += (t2 - t1) + (t7 - t6)
        s.t_insert += t3 - t2
        s.t_st += t5 - t4
        s.t_sim += t6 - t5
        s.t_backup += t8 - t7
        return slot_sel

    # -- one MCTS step (paper Fig. 1): build tree to X nodes, act, flush
    def run_step(self, max_supersteps: int = 10_000, reuse_subtree: bool = False):
        """reuse_subtree=True replaces the paper's full Tree Flush with a
        statistics-preserving re-root (core.reroot, beyond-paper): every
        simulation spent under the chosen action carries into the next
        step.  Requires a jax executor (host reroot feeds jnp arrays)."""
        size0 = int(np.asarray(self._size()))
        steps = 0
        while int(np.asarray(self._size())) < self.cfg.X and steps < max_supersteps:
            self.superstep()
            steps += 1
            new_size = int(np.asarray(self._size()))
            if new_size == size0:  # saturated (all leaves terminal/at depth cap)
                break
            size0 = new_size
        a = self.exec.best_action(self.tree)
        new_root_state, reward, term = self.env.step(self.root_state, a)
        snap = self.exec.snapshot(self.tree) if reuse_subtree else None
        self.root_state = new_root_state
        if reuse_subtree and not term and not isinstance(
                self.exec, ReferenceExecutor):
            from repro.core import reroot
            new_root = int(snap["child"][int(snap["root"]), a])
            if new_root != NULL:
                import jax.numpy as jnp
                self.tree, old2new = reroot.reroot_tree(
                    self.cfg, snap, new_root, jnp)
                self.st.compact(old2new)
                return a, reward, term
        # paper-faithful full flush
        k = 0 if term else self.env.num_actions(new_root_state)
        self.tree = self.exec.init(max(k, 1))
        self.st.flush(new_root_state)
        return a, reward, term

    def _size(self):
        return self.tree.size

    def close(self):
        """Release expansion-engine resources (process pool, if any)."""
        self.expander.close()
