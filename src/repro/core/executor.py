"""Unified in-tree executor stack: one protocol, every backend, any G.

Before this module the repo carried two parallel executor hierarchies:
single-tree executors in core.mcts (stateless, tree passed in and out,
Pallas variant included) and arena executors in service.arena (stateful
over G stacked slots, Pallas gated out because the old kernels managed
their own grid).  Mirsoleimani et al.'s *Structured Parallel Programming
for MCTS* argues for exactly one execution abstraction across
parallelization patterns — this module is that collapse:

  InTreeExecutor        — the protocol.  Every implementation drives G >= 1
                          tree slots through the device phases (Selection /
                          Insertion / finalize / BackUp) under a [G] active
                          mask.  TreeParallelMCTS is the G=1 client,
                          SearchService the G>1 client; both share this
                          dispatch instead of duplicating it.
  ReferenceExecutor     — the paper's CPU-only master process: one
                          sequential numpy MutableTree per slot, looped on
                          host.  Correctness oracle and CPU baseline.
  JaxExecutor           — stacked trees + vmapped jit ops ("faithful",
                          "relaxed", "wavefront" variants).
  PallasExecutor        — the arena-native [G]-grid kernels
                          (kernels.uct_select / uct_backup): Selection and
                          BackUp in one kernel launch per phase for all
                          slots, insertion/finalize on the vmapped jit path
                          (host-coupled scatters), straggler-masked backups
                          on the jit fallback.  Bit-compatible with the
                          reference per slot.

Slot compaction: `gather_sub` extracts the active slots into a dense
sub-executor (padded to a power of two so the jit/kernel program cache
stays bounded) and `scatter_sub` writes the results back — the service
scheduler uses this at low occupancy so idle slots stop costing masked
device work (ROADMAP item).  Per-slot arithmetic is position-independent,
so compaction never changes what a slot computes.

Persistent compaction sessions: the paper's accelerator wins by keeping
the tree device-resident across supersteps (§IV), and BENCH_service.json
showed that re-gathering the sub-arena every superstep costs more than
the masked work it saves.  `open_session` wraps gather/scatter in a
CompactionSession that keeps the dense sub-arena resident: the gather
happens once, supersteps accumulate in the sub-executor with
dirty-tracking, and the scatter back into the full arena is deferred to
session close or an explicit `sync` (snapshot reads).  Membership
changes (admission / eviction / reroot rewrites) invalidate the session
— the pool closes and reopens it — so a stable active set pays one
gather + one scatter total instead of one per superstep.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import intree, ref_sequential as ref
from repro.core.tree import (
    NULL, TreeConfig, UCTree, arena_set_slot, arena_slot, init_arena,
    init_tree, to_jax,
)
from repro.obs.trace import NULL_TRACER

EXECUTOR_NAMES = ("reference", "faithful", "relaxed", "wavefront", "pallas")


class InTreeExecutor(Protocol):
    """The in-tree accelerator contract (paper §IV, lifted to G slots).

    All array arguments follow the stacked convention: `active` is a [G]
    bool mask, selection results / sim nodes / values carry a leading [G]
    axis, and finalize takes the fixed-width NULL-padded per-slot rows of
    HostExpansion.padded_finalize_args.  Inactive slots must come back
    bit-frozen from every phase.
    """

    cfg: TreeConfig
    G: int

    def reset_slot(self, g: int, root_num_actions: int) -> None: ...
    def selection(self, active: np.ndarray, p: int): ...
    def insert(self, active: np.ndarray, sel) -> np.ndarray: ...
    def finalize(self, nodes, num_actions, terminal, prior_parent,
                 priors_fx) -> None: ...
    def backup(self, active, sel, sim_nodes, values_fx, alternating: bool,
               dropped=None) -> None: ...
    # OPTIONAL fused fast path (device executors only — the reference
    # executor keeps the phase-by-phase oracle): run up to K supersteps
    # in one compiled program; see repro.core.fused.  Absence of the
    # attribute means "host path only" (probe with hasattr).
    def run_supersteps(self, active, p: int, K: int, env, sim, states,
                       budget_left, alternating: bool): ...
    def sel_to_host(self, sel) -> dict: ...
    def best_actions(self) -> np.ndarray: ...
    def sizes(self) -> np.ndarray: ...
    def slot_snapshot(self, g: int) -> dict: ...
    def write_slot(self, g: int, arrays: dict) -> None: ...
    def block(self) -> None: ...
    def release(self) -> None: ...
    def gather_sub(self, slot_idx: np.ndarray, Gc: int) -> "InTreeExecutor": ...
    def scatter_sub(self, sub: "InTreeExecutor", slot_idx: np.ndarray) -> None: ...
    def open_session(self, slot_idx: np.ndarray, Gc: int,
                     tracer=None, tid: int = 0) -> "CompactionSession": ...
    # single-tree compat surface (the G=1 client's `tree` property and
    # snapshot/action helpers used throughout tests and examples)
    def init(self, root_num_actions: int): ...
    def get_tree(self, g: int = 0): ...
    def set_tree(self, tree, g: int = 0) -> None: ...
    def snapshot(self, tree) -> dict: ...
    def best_action(self, tree) -> int: ...


class CompactionSession:
    """Device-resident dense sub-arena spanning one fixed active set.

    Built on any InTreeExecutor's gather_sub/scatter_sub, so every backend
    (reference / faithful / relaxed / wavefront / pallas) gets persistent
    compaction through the same object.  Lifecycle:

      open   — ONE gather_sub copies the active slots into `sub` (dense,
               pow2-padded); the session then stays resident.
      dirty  — `mark_superstep` records that `sub` holds updates the full
               arena has not seen; `sync` scatters them back WITHOUT
               closing (snapshot reads force this), after which `sub`
               keeps accumulating.
      close  — final sync + the session refuses further use.  The owning
               pool closes on any membership change (admit / evict) or
               content rewrite of a member slot (reroot / reset), since a
               host-side write to the full arena would make `sub` stale.

    `matches` is the reuse test: same slot set, same padded width, still
    open.  A stable active set therefore pays one gather and one scatter
    total, however many supersteps it stays stable — the serving analogue
    of the paper keeping the tree SRAM-resident across supersteps.
    """

    def __init__(self, parent: "InTreeExecutor", slot_idx: np.ndarray,
                 Gc: int, tracer=None, tid: int = 0):
        self.parent = parent
        self.slot_idx = np.asarray(slot_idx, np.int32).copy()
        self.Gc = int(Gc)
        # obs: gather/scatter spans on the owning pool's trace track.
        # When tracing is live the gather/scatter are fenced with
        # block_until_ready so the copy cost is attributed to the span
        # instead of leaking into whichever phase next touches the arena.
        self.trace = NULL_TRACER if tracer is None else tracer
        self.tid = tid
        with self.trace.span("compact-gather", cat="compact", tid=tid,
                             slots=len(self.slot_idx), Gc=self.Gc):
            self.sub = parent.gather_sub(self.slot_idx, self.Gc)
            if self.trace.enabled:
                self.sub.block()
        self.dirty = False
        self.open = True
        self.supersteps = 0

    @property
    def A(self) -> int:
        return len(self.slot_idx)

    def matches(self, slot_idx: np.ndarray, Gc: int) -> bool:
        return (self.open and self.Gc == int(Gc)
                and len(slot_idx) == self.A
                and bool(np.array_equal(self.slot_idx, slot_idx)))

    def owns(self, g: int) -> bool:
        return self.open and bool(np.any(self.slot_idx == g))

    def mark_superstep(self):
        assert self.open, "superstep on a closed CompactionSession"
        self.dirty = True
        self.supersteps += 1

    def sync(self) -> bool:
        """Scatter pending sub-arena updates back; True if one happened."""
        if self.dirty:
            with self.trace.span("compact-scatter", cat="compact",
                                 tid=self.tid, slots=len(self.slot_idx)):
                self.parent.scatter_sub(self.sub, self.slot_idx)
                if self.trace.enabled:
                    self.parent.block()
            self.dirty = False
            return True
        return False

    def close(self) -> bool:
        """Final sync; the session is unusable afterwards.  True if the
        close actually scattered."""
        scattered = self.sync() if self.open else False
        self.open = False
        return scattered


def _sel_to_host(sel) -> dict:
    """One Receive-buffer transfer: device selection result -> host numpy."""
    if isinstance(sel, dict):
        return sel
    d = {
        "path_nodes": sel.path_nodes, "path_actions": sel.path_actions,
        "depths": sel.depths, "leaves": sel.leaves,
        "expand_action": sel.expand_action, "n_insert": sel.n_insert,
        "insert_base": sel.insert_base,
    }
    return {k: np.asarray(v) for k, v in jax.device_get(d).items()}


class JaxExecutor:
    """Vmapped jit in-tree operations over G stacked trees.

    `device` commits the arena to one specific device (multi-device
    serving: core/sharded.py builds one executor per shard).  Every op —
    eager and jit — then follows the committed placement, and the host
    uploads (active masks, finalize rows, sim states) stay uncommitted
    so XLA moves them to the arena's device automatically.  None keeps
    the historical default-device placement.
    """

    def __init__(self, cfg: TreeConfig, G: int, variant: str = "faithful",
                 _trees: Optional[UCTree] = None, device=None):
        if variant not in ("faithful", "relaxed", "wavefront"):
            raise NotImplementedError(
                f"JaxExecutor variant {variant!r}: the vmappable jit paths "
                "are faithful/relaxed/wavefront (the arena-native Pallas "
                "kernels are PallasExecutor / executor='pallas')")
        self.cfg, self.G, self.variant = cfg, G, variant
        self._fused_variant = variant
        self.device = device
        self.trees = init_arena(cfg, G) if _trees is None else _trees
        if device is not None and _trees is None:
            from repro.models.sharding import put_on_device
            self.trees = put_on_device(self.trees, device)

    # -- device phases -------------------------------------------------
    def selection(self, active: np.ndarray, p: int):
        self.trees, sel = intree.select_arena(
            self.cfg, self.trees, jnp.asarray(active), p, self.variant)
        return sel

    def insert(self, active: np.ndarray, sel):
        return self.insert_host(self.insert_dev(active, sel))

    def insert_dev(self, active: np.ndarray, sel):
        """Dispatch Node Insertion and return the DEVICE id block without
        reading it back — the overlap mode stages a gang's select+insert
        asynchronously and defers the (blocking) host read to
        insert_host() when that gang's host half actually starts."""
        self.trees, new_nodes = intree.insert_arena(
            self.cfg, self.trees, jnp.asarray(active), sel)
        return new_nodes

    def insert_host(self, new_nodes):
        """Blocking half of insert(): fetch the staged [G, p, Fp] id block
        to host.  insert() == insert_host(insert_dev(...)) bit-exactly."""
        return np.asarray(jax.device_get(new_nodes))

    def finalize(self, nodes, num_actions, terminal, prior_parent, priors_fx):
        self.trees = intree.finalize_arena(
            self.trees, jnp.asarray(nodes), jnp.asarray(num_actions),
            jnp.asarray(terminal), jnp.asarray(prior_parent),
            jnp.asarray(priors_fx))

    def backup(self, active, sel, sim_nodes, values_fx, alternating: bool,
               dropped=None):
        if dropped is not None:
            self.trees = intree.backup_arena(
                self.cfg, self.trees, jnp.asarray(active), sel,
                jnp.asarray(sim_nodes), jnp.asarray(values_fx), alternating,
                True, np.asarray(dropped))
        else:
            self.trees = intree.backup_arena(
                self.cfg, self.trees, jnp.asarray(active), sel,
                jnp.asarray(sim_nodes), jnp.asarray(values_fx), alternating)
        # No fence: JAX async dispatch overlaps the backup with the host
        # side of the next superstep; readers (sizes/best_actions/
        # snapshots) block on the value they fetch, and the obs layer
        # fences per-phase via block() when tracing.

    # -- fused multi-superstep dispatch --------------------------------
    def run_supersteps(self, active, p: int, K: int, env, sim, states,
                       budget_left, alternating: bool):
        """Up to K fused supersteps in one compiled program (see
        repro.core.fused).  Mutates self.trees; returns FusedDispatch."""
        from repro.core import fused

        self.trees, disp = fused.run_supersteps(
            self.cfg, self._fused_variant, self.trees, np.asarray(active),
            p, K, env, sim, states, budget_left, alternating)
        return disp

    def run_supersteps_submit(self, active, p: int, K: int, env, sim,
                              states, budget_left, alternating: bool):
        """Non-blocking half of run_supersteps: queue the fused program
        and return a PendingDispatch of device outputs WITHOUT any host
        read — the overlap mode's staged fused dispatch."""
        from repro.core import fused

        self.trees, pend = fused.submit_supersteps(
            self.cfg, self._fused_variant, self.trees, np.asarray(active),
            p, K, env, sim, states, budget_left, alternating)
        return pend

    def run_supersteps_collect(self, pend):
        """Blocking half: fetch the escape scalars / host views of a
        staged dispatch.  run_supersteps == collect(submit(...))."""
        from repro.core import fused

        return fused.collect_supersteps(pend)

    # -- host-side slot access -----------------------------------------
    def reset_slot(self, g: int, root_num_actions: int):
        self.trees = arena_set_slot(
            self.trees, g, init_tree(self.cfg, root_num_actions))

    def sel_to_host(self, sel) -> dict:
        return _sel_to_host(sel)

    def best_actions(self) -> np.ndarray:
        return np.asarray(jax.device_get(
            intree.best_root_action_arena(self.trees)))

    def sizes(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.trees.size))

    def slot_snapshot(self, g: int) -> dict:
        one = jax.device_get(arena_slot(self.trees, g))
        return {k: np.asarray(v) for k, v in dataclasses.asdict(one).items()}

    def write_slot(self, g: int, arrays: dict):
        self.trees = arena_set_slot(self.trees, g, to_jax(UCTree(**arrays)))

    def block(self):
        jax.block_until_ready(self.trees.size)

    def release(self):
        """Drop the arena's device arrays (cold-pool retirement).  The
        executor is unusable afterwards — a retired pool builds a fresh
        one on resurrection instead of reviving this object."""
        self.trees = None

    # -- compaction (gather active slots into a dense sub-arena) -------
    def _spawn(self, trees: UCTree, Gc: int) -> "JaxExecutor":
        # gathered trees inherit the parent's committed placement, so the
        # sub-executor records the same device without a fresh device_put
        return JaxExecutor(self.cfg, Gc, self.variant, _trees=trees,
                           device=self.device)

    def gather_sub(self, slot_idx: np.ndarray, Gc: int) -> "JaxExecutor":
        idx = np.asarray(slot_idx, np.int32)
        pad = np.full(Gc - len(idx), idx[0], np.int32)  # masked-off filler
        gidx = jnp.asarray(np.concatenate([idx, pad]))
        return self._spawn(jax.tree.map(lambda a: a[gidx], self.trees), Gc)

    def scatter_sub(self, sub: "JaxExecutor", slot_idx: np.ndarray):
        idx = jnp.asarray(np.asarray(slot_idx, np.int32))
        a = len(slot_idx)
        self.trees = jax.tree.map(
            lambda full, s: full.at[idx].set(s[:a]), self.trees, sub.trees)

    def open_session(self, slot_idx: np.ndarray, Gc: int,
                     tracer=None, tid: int = 0) -> CompactionSession:
        return CompactionSession(self, slot_idx, Gc, tracer=tracer, tid=tid)

    # -- single-tree compat surface (G=1 driver / tests) ---------------
    def init(self, root_num_actions: int) -> UCTree:
        return init_tree(self.cfg, root_num_actions)

    def get_tree(self, g: int = 0) -> UCTree:
        return arena_slot(self.trees, g)

    def set_tree(self, tree: UCTree, g: int = 0):
        self.trees = arena_set_slot(self.trees, g, to_jax(tree))

    def snapshot(self, tree) -> dict:
        return {k: np.asarray(v) for k, v in dataclasses.asdict(
            jax.device_get(tree)).items()}

    def best_action(self, tree) -> int:
        return int(intree.best_root_action(tree))


class PallasExecutor(JaxExecutor):
    """Arena-native Pallas kernels behind the same executor contract.

    Selection and BackUp run as ONE [G]-grid kernel launch each (per-slot
    VMEM blocks, scalar-prefetched root/size/active, idle slots no-op in
    the kernel).  Insertion and finalize stay on the vmapped jit path —
    they are host-coupled scatters, not the SRAM-resident hot loop the
    paper accelerates.  Straggler-masked backups (fault policy) fall back
    to the jit masked path; the kernel covers the fault-free superstep.
    """

    def __init__(self, cfg: TreeConfig, G: int,
                 _trees: Optional[UCTree] = None, device=None):
        super().__init__(cfg, G, "faithful", _trees=_trees, device=device)
        self._fused_variant = "pallas"
        from repro.kernels import ops as kops  # lazy: keeps core import-light
        self._kops = kops

    def selection(self, active: np.ndarray, p: int):
        self.trees, sel = self._kops.select_arena(
            self.cfg, self.trees, jnp.asarray(active), p)
        return sel

    def backup(self, active, sel, sim_nodes, values_fx, alternating: bool,
               dropped=None):
        if dropped is not None:
            return super().backup(active, sel, sim_nodes, values_fx,
                                  alternating, dropped)
        self.trees = self._kops.backup_arena(
            self.cfg, self.trees, jnp.asarray(active), sel,
            jnp.asarray(sim_nodes), jnp.asarray(values_fx), alternating)
        # no fence — same async-dispatch contract as JaxExecutor.backup

    def _spawn(self, trees: UCTree, Gc: int) -> "PallasExecutor":
        return PallasExecutor(self.cfg, Gc, _trees=trees, device=self.device)


class ReferenceExecutor:
    """The paper's CPU-only master process: one sequential numpy
    MutableTree per slot, looped on host.

    Same interface and same stacked [G, ...] host-array convention as the
    device executors so every client is executor-agnostic; inactive slots
    produce zero rows the driver never reads.
    """

    def __init__(self, cfg: TreeConfig, G: int, _trees: Optional[list] = None):
        self.cfg, self.G = cfg, G
        self.trees = (
            [ref.MutableTree.from_tree(init_tree(cfg, xp=np))
             for _ in range(G)] if _trees is None else _trees)

    # -- phases --------------------------------------------------------
    def selection(self, active: np.ndarray, p: int) -> dict:
        cfg = self.cfg
        out = {
            "path_nodes": np.full((self.G, p, cfg.D), NULL, np.int32),
            "path_actions": np.full((self.G, p, cfg.D), NULL, np.int32),
            "depths": np.zeros((self.G, p), np.int32),
            "leaves": np.zeros((self.G, p), np.int32),
            "expand_action": np.full((self.G, p), NULL, np.int32),
            "n_insert": np.zeros((self.G, p), np.int32),
            "insert_base": np.zeros((self.G, p), np.int32),
        }
        for g in np.flatnonzero(active):
            t = self.trees[g]
            sel = ref.selection_phase(cfg, t, p)
            ni = sel["n_insert"]
            sel["insert_base"] = t.size + np.cumsum(ni) - ni
            for k, v in sel.items():
                out[k][g] = v
        return out

    def insert(self, active: np.ndarray, sel: dict) -> np.ndarray:
        p = sel["leaves"].shape[1]
        new_nodes = np.full((self.G, p, self.cfg.Fp), NULL, np.int32)
        for g in np.flatnonzero(active):
            slot_sel = {k: v[g] for k, v in sel.items()}
            new_nodes[g] = ref.insert_phase(self.cfg, self.trees[g], slot_sel)
        return new_nodes

    # async split: numpy has no device, so "dev" computes and "host" is
    # identity — the overlap schedule runs unchanged on the oracle
    def insert_dev(self, active: np.ndarray, sel: dict) -> np.ndarray:
        return self.insert(active, sel)

    def insert_host(self, new_nodes: np.ndarray) -> np.ndarray:
        return new_nodes

    def finalize(self, nodes, num_actions, terminal, prior_parent, priors_fx):
        for g in range(self.G):
            ref.finalize_expansion(
                self.trees[g], nodes[g], num_actions[g], terminal[g],
                prior_parent[g], priors_fx[g])

    def backup(self, active, sel, sim_nodes, values_fx, alternating: bool,
               dropped=None):
        for g in np.flatnonzero(active):
            slot_sel = {k: v[g] for k, v in sel.items()}
            ref.backup_phase(self.cfg, self.trees[g], slot_sel,
                             sim_nodes[g], values_fx[g], alternating,
                             None if dropped is None else dropped[g])

    # -- host-side slot access -----------------------------------------
    def reset_slot(self, g: int, root_num_actions: int):
        self.trees[g] = ref.MutableTree.from_tree(
            init_tree(self.cfg, root_num_actions, xp=np))

    def sel_to_host(self, sel) -> dict:
        return sel

    def best_actions(self) -> np.ndarray:
        return np.array([ref.best_root_action(self.cfg, t)
                         for t in self.trees], np.int32)

    def sizes(self) -> np.ndarray:
        return np.array([t.size for t in self.trees], np.int32)

    def slot_snapshot(self, g: int) -> dict:
        return {k: np.asarray(v) for k, v in
                dataclasses.asdict(self.trees[g].to_tree()).items()}

    def write_slot(self, g: int, arrays: dict):
        self.trees[g] = ref.MutableTree.from_tree(UCTree(**arrays))

    def block(self):
        pass

    def release(self):
        self.trees = None

    # -- compaction -----------------------------------------------------
    # MutableTrees mutate in place, so the sub-executor shares the slot
    # objects and scatter is a re-link; compaction is a no-op cost-wise on
    # the host oracle but keeps the scheduler executor-agnostic.
    def gather_sub(self, slot_idx: np.ndarray, Gc: int) -> "ReferenceExecutor":
        idx = list(np.asarray(slot_idx))
        shared = [self.trees[g] for g in idx]
        shared += [self.trees[idx[0]]] * (Gc - len(idx))  # masked-off filler
        return ReferenceExecutor(self.cfg, Gc, _trees=shared)

    def scatter_sub(self, sub: "ReferenceExecutor", slot_idx: np.ndarray):
        for i, g in enumerate(np.asarray(slot_idx)):
            self.trees[g] = sub.trees[i]

    def open_session(self, slot_idx: np.ndarray, Gc: int,
                     tracer=None, tid: int = 0) -> CompactionSession:
        return CompactionSession(self, slot_idx, Gc, tracer=tracer, tid=tid)

    # -- single-tree compat surface ------------------------------------
    def init(self, root_num_actions: int):
        return ref.MutableTree.from_tree(
            init_tree(self.cfg, root_num_actions, xp=np))

    def get_tree(self, g: int = 0):
        return self.trees[g]

    def set_tree(self, tree, g: int = 0):
        self.trees[g] = (tree if isinstance(tree, ref.MutableTree)
                         else ref.MutableTree.from_tree(tree))

    def snapshot(self, tree) -> dict:
        return {k: np.asarray(v) for k, v in
                dataclasses.asdict(tree.to_tree()).items()}

    def best_action(self, tree) -> int:
        return ref.best_root_action(self.cfg, tree)


def make_intree_executor(cfg: TreeConfig, G: int, name: str,
                         n_shards: int = 1,
                         devices: Optional[list] = None) -> InTreeExecutor:
    """Executor factory shared by TreeParallelMCTS (G=1) and the service
    pools.  `n_shards > 1` partitions the G slots across D per-device
    child executors behind one ShardedExecutor (core/sharded.py): slot g
    lives on shard g // (G // D), each shard's arena committed to its own
    device (`devices`, defaulting to launch.mesh.serving_devices).  The
    per-slot computation is position- and device-independent, so sharding
    never changes what a slot computes."""
    if n_shards > 1:
        from repro.core.sharded import make_sharded_executor
        return make_sharded_executor(cfg, G, name, n_shards, devices)
    device = devices[0] if devices else None
    if name == "reference":
        return ReferenceExecutor(cfg, G)
    if name == "pallas":
        return PallasExecutor(cfg, G, device=device)
    return JaxExecutor(cfg, G, name, device=device)
