"""Sequential CPU reference for p-worker Tree-Parallel MCTS (paper Alg. 1/2).

This is the baseline the paper accelerates: a single master process doing
in-tree operations for p workers in worker order, with virtual loss applied
inside the critical region.  It serves two roles here:

  1. the correctness ORACLE — the paper proves its accelerator produces
     "the exact same outputs as that of a CPU-only system"; our batched
     jit ops and Pallas kernels are tested bit-exactly against this module;
  2. the CPU-ONLY BASELINE of the benchmarks (Fig. 4 / Fig. 5 analogues).

Everything here is plain numpy, deliberately unvectorized across workers
(that is the point of the baseline).  Scoring goes through the shared
backend-generic routine in scoring.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import fixedpoint as fx
from repro.core import scoring
from repro.core.tree import NULL, TreeConfig, UCTree


@dataclasses.dataclass
class MutableTree:
    """Mutable numpy mirror of UCTree for the in-place sequential program."""

    child: np.ndarray
    edge_N: np.ndarray
    edge_W: np.ndarray
    edge_VL: np.ndarray
    edge_P: np.ndarray
    node_N: np.ndarray
    node_O: np.ndarray
    num_expanded: np.ndarray
    num_actions: np.ndarray
    node_depth: np.ndarray
    terminal: np.ndarray
    size: int
    root: int
    log_table: np.ndarray

    @classmethod
    def from_tree(cls, t: UCTree) -> "MutableTree":
        return cls(
            child=np.array(t.child, dtype=np.int32),
            edge_N=np.array(t.edge_N, dtype=np.int32),
            edge_W=np.array(t.edge_W, dtype=np.int32),
            edge_VL=np.array(t.edge_VL, dtype=np.int32),
            edge_P=np.array(t.edge_P, dtype=np.int32),
            node_N=np.array(t.node_N, dtype=np.int32),
            node_O=np.array(t.node_O, dtype=np.int32),
            num_expanded=np.array(t.num_expanded, dtype=np.int32),
            num_actions=np.array(t.num_actions, dtype=np.int32),
            node_depth=np.array(t.node_depth, dtype=np.int32),
            terminal=np.array(t.terminal, dtype=np.int32),
            size=int(t.size),
            root=int(t.root),
            log_table=np.array(t.log_table, dtype=np.float32),
        )

    def to_tree(self) -> UCTree:
        return UCTree(
            child=self.child, edge_N=self.edge_N, edge_W=self.edge_W,
            edge_VL=self.edge_VL, edge_P=self.edge_P, node_N=self.node_N,
            node_O=self.node_O, num_expanded=self.num_expanded,
            num_actions=self.num_actions, node_depth=self.node_depth,
            terminal=self.terminal, size=np.int32(self.size),
            root=np.int32(self.root), log_table=self.log_table,
        )


def _node_scores(cfg: TreeConfig, t: MutableTree, node: int) -> np.ndarray:
    return scoring.edge_scores_fx(
        cfg,
        child=t.child[node],
        edge_N=t.edge_N[node],
        edge_W=t.edge_W[node],
        edge_VL=t.edge_VL[node],
        edge_P=t.edge_P[node],
        node_N=t.node_N[node : node + 1],
        node_O=t.node_O[node : node + 1],
        num_actions=t.num_actions[node : node + 1],
        log_table=t.log_table,
        xp=np,
    )


def _is_leaf(cfg: TreeConfig, t: MutableTree, node: int, depth: int) -> bool:
    return bool(
        scoring.is_leaf(
            cfg,
            num_expanded=t.num_expanded[node],
            num_actions=t.num_actions[node],
            terminal=t.terminal[node],
            depth=depth,
            xp=np,
        )
    )


def select_one(cfg: TreeConfig, t: MutableTree):
    """Alg. 1 SELECTION for one worker: descend, applying virtual loss.

    Returns (path_nodes[D], path_actions[D], depth, leaf).  Arrays are
    NULL-padded beyond `depth`.
    """
    path_nodes = np.full(cfg.D, NULL, dtype=np.int32)
    path_actions = np.full(cfg.D, NULL, dtype=np.int32)
    node = t.root
    t.node_O[node] += 1
    depth = 0
    while not _is_leaf(cfg, t, node, depth):
        scores = _node_scores(cfg, t, node)
        a = int(scoring.argmax_first(scores, xp=np))
        t.edge_VL[node, a] += 1                      # Alg. 1 line 5 (RAW region)
        path_nodes[depth] = node
        path_actions[depth] = a
        node = int(t.child[node, a])
        t.node_O[node] += 1
        depth += 1
    return path_nodes, path_actions, depth, node


def selection_phase(cfg: TreeConfig, t: MutableTree, p: int):
    """All p workers' Selections, strictly in worker order (the sequential
    semantics the paper's pipeline reproduces), followed by the BSP
    expansion-assignment post-pass.

    Returns dict with per-worker paths, leaves, depths and expansion plan:
      expand_action[j] : action index to expand, NULL if none,
                         -2 means "expand all legal actions" (expand_all).
      n_insert[j]      : how many nodes worker j will insert.
    """
    path_nodes = np.full((p, cfg.D), NULL, dtype=np.int32)
    path_actions = np.full((p, cfg.D), NULL, dtype=np.int32)
    depths = np.zeros(p, dtype=np.int32)
    leaves = np.zeros(p, dtype=np.int32)
    for j in range(p):
        pn, pa, d, leaf = select_one(cfg, t)
        path_nodes[j], path_actions[j] = pn, pa
        depths[j], leaves[j] = d, leaf

    expand_action = np.full(p, NULL, dtype=np.int32)
    n_insert = np.zeros(p, dtype=np.int32)
    budget = cfg.X - t.size
    pending: dict[int, int] = {}
    claimed: set[int] = set()
    for j in range(p):
        leaf = int(leaves[j])
        if t.terminal[leaf] or depths[j] >= cfg.D:
            continue
        if cfg.expand_all:
            if leaf in claimed or t.num_expanded[leaf] > 0:
                continue
            k = int(t.num_actions[leaf])
            if k == 0 or budget < k:
                continue
            claimed.add(leaf)
            expand_action[j] = -2
            n_insert[j] = k
            budget -= k
        else:
            a = int(t.num_expanded[leaf]) + pending.get(leaf, 0)
            if a >= int(t.num_actions[leaf]) or budget < 1:
                continue
            pending[leaf] = pending.get(leaf, 0) + 1
            expand_action[j] = a
            n_insert[j] = 1
            budget -= 1
    return dict(
        path_nodes=path_nodes, path_actions=path_actions, depths=depths,
        leaves=leaves, expand_action=expand_action, n_insert=n_insert,
    )


def insert_phase(cfg: TreeConfig, t: MutableTree, sel: dict) -> np.ndarray:
    """Alg. 1 EXPANSION tree half: allocate node ids, link edges.

    Returns new_nodes[p, Fp] (NULL-padded): worker j's inserted node ids
    (one for single-expand; num_actions[leaf] for expand_all).
    """
    p = sel["leaves"].shape[0]
    new_nodes = np.full((p, cfg.Fp), NULL, dtype=np.int32)
    for j in range(p):
        leaf = int(sel["leaves"][j])
        ea = int(sel["expand_action"][j])
        if ea == NULL:
            continue
        actions = range(int(t.num_actions[leaf])) if ea == -2 else [ea]
        for i, a in enumerate(actions):
            nid = t.size
            t.size += 1
            t.child[leaf, a] = nid
            t.node_depth[nid] = t.node_depth[leaf] + 1
            t.num_actions[nid] = cfg.F        # refined by finalize_expansion
            t.num_expanded[leaf] += 1
            new_nodes[j, i] = nid
    return new_nodes


def finalize_expansion(
    t: MutableTree,
    nodes: np.ndarray,        # [k] node ids
    num_actions: np.ndarray,  # [k]
    terminal: np.ndarray,     # [k]
    prior_parent: np.ndarray | None = None,  # [k] parent ids for priors
    priors_fx: np.ndarray | None = None,     # [k, Fp] Qm.16
):
    """Host metadata write-back after the 1-step simulations."""
    for i, n in enumerate(np.asarray(nodes, dtype=np.int64)):
        if n == NULL:
            continue
        t.num_actions[n] = num_actions[i]
        t.terminal[n] = terminal[i]
    if priors_fx is not None:
        for i, pa in enumerate(np.asarray(prior_parent, dtype=np.int64)):
            if pa == NULL:
                continue
            t.edge_P[pa] = priors_fx[i]


def backup_phase(
    cfg: TreeConfig,
    t: MutableTree,
    sel: dict,
    sim_nodes: np.ndarray,   # [p] node the simulation ran from
    values_fx: np.ndarray,   # [p] Qm.16 simulation rewards
    alternating_signs: bool = False,
    dropped: np.ndarray | None = None,   # [p] bool: recover-only workers
):
    """Alg. 1 BACKUP for all p workers in worker order.

    Updates every traversed edge (recovering VL) plus the expansion edge
    when one exists (WU-UCT convention: the simulated node's reward seeds
    its in-edge), all in exact Qm.16 integer arithmetic.  `dropped`
    workers (straggler policy) only recover their virtual loss.
    """
    p = sim_nodes.shape[0]
    for j in range(p):
        alive = dropped is None or not dropped[j]
        v = np.int32(values_fx[j])
        depth = int(sel["depths"][j])
        leaf = int(sel["leaves"][j])
        ea = int(sel["expand_action"][j])
        # sim_depth: depth of the node whose value v is measured from.
        sim_depth = depth + (1 if (ea != NULL and ea != -2 and not cfg.expand_all) else 0)
        for d in range(depth):
            node = int(sel["path_nodes"][j, d])
            a = int(sel["path_actions"][j, d])
            sign = -1 if (alternating_signs and (sim_depth - d) % 2 == 1) else 1
            if alive:
                t.edge_N[node, a] += 1
                t.edge_W[node, a] += np.int32(sign) * v
                t.node_N[node] += 1
            t.edge_VL[node, a] -= 1
            t.node_O[node] -= 1
        if alive:
            t.node_N[leaf] += 1
        t.node_O[leaf] -= 1
        if alive and ea != NULL and ea != -2 and not cfg.expand_all:
            nid = int(sim_nodes[j])
            # Expansion edge sits at depth `depth`; same sign rule as above
            # (alternating games: v is from the sim node's player, the edge
            # belongs to the leaf's player => flipped).
            sign = -1 if (alternating_signs and (sim_depth - depth) % 2 == 1) else 1
            t.edge_N[leaf, ea] += 1
            t.edge_W[leaf, ea] += np.int32(sign) * v
            t.node_N[nid] += 1


def best_root_action(cfg: TreeConfig, t: MutableTree) -> int:
    """Agent action at an MCTS step boundary: robust child (max edge_N),
    ties broken toward max uct score then lowest index."""
    n = t.edge_N[t.root].astype(np.int64)
    lane_ok = (np.arange(cfg.Fp) < t.num_actions[t.root]) & (t.child[t.root] != NULL)
    n = np.where(lane_ok, n, -1)
    return int(np.argmax(n))
