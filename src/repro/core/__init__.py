from repro.core.tree import TreeConfig, UCTree, init_tree, NULL
from repro.core.executor import (
    InTreeExecutor, JaxExecutor, PallasExecutor, ReferenceExecutor,
    make_intree_executor,
)
from repro.core.expand import (
    EXPANSION_MODES, ExpansionEngine, HostExpansion, host_expand_phase,
)
from repro.core.mcts import TreeParallelMCTS, RolloutBackend, make_executor
from repro.core.state_table import StateTable
from repro.core import fixedpoint, intree, ref_sequential, scoring

__all__ = [
    "TreeConfig", "UCTree", "init_tree", "NULL", "TreeParallelMCTS",
    "RolloutBackend", "InTreeExecutor", "JaxExecutor", "PallasExecutor",
    "ReferenceExecutor", "make_executor", "make_intree_executor",
    "EXPANSION_MODES", "ExpansionEngine", "HostExpansion",
    "host_expand_phase",
    "StateTable", "fixedpoint", "intree", "ref_sequential", "scoring",
]
