"""ShardedExecutor — one InTreeExecutor over D per-device child arenas.

The fleet axis (ROADMAP item 1): everything above this module keeps
talking about "G slots", and this module is the layer that makes those G
slots mean "D devices x G_shard slots each".  The serving pool's slot
axis is partitioned into D contiguous runs — slot g is owned by shard
g // G_shard — and each shard holds its own child executor (JaxExecutor /
PallasExecutor / ReferenceExecutor) whose arena is committed to one
device via models.sharding.put_on_device.  Dispatch is explicit
per-device (the `jax.devices()` route): each protocol call slices its
[G]-leading arguments into per-shard blocks, invokes every child — JAX's
async dispatch queues all shards' device programs before any transfer
blocks — and reassembles the [G]-shaped result on host.

Why explicit dispatch instead of shard_map: the superstep phases are
already host-mediated at the pool level (expansion / simulation hand-offs
between every device phase), so a collective-free per-device program per
shard gives the same placement with none of the SPMD constraints — and it
degrades gracefully when fewer physical devices exist than shards
(launch.mesh.serving_devices wraps round-robin, so tests exercise the
partition logic on any host; CI runs the real thing under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``).

Bit-identity: per-slot arithmetic is position- and device-independent
(the same property masked/compacted execution already relies on), so a
sharded pool computes bit-identically to the single-device arena for
every request — placement is scheduling, not semantics.  Pinned by the
D=1..4 legs of tests/test_executor_matrix.py.

Compaction composes: `gather_sub` splits the (sorted) active-slot index
into its per-shard runs and gathers a dense pow2-padded sub-arena on
EACH device, presenting them as one ShardedExecutor whose global rows
[0, A) are the active slots in slot order (shard runs are contiguous
because slot ids are monotonic in shard id).  One CompactionSession over
the sharded executor therefore keeps D device-resident sub-arenas — one
per device — behind the session API the pool already speaks.

The fused K-superstep path stays per-shard by construction: the pool
dispatches each child's `run_supersteps` separately (each shard runs to
its own commit/expansion escape on its own device) — see
ArenaPool.fused_dispatch.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.tree import NULL, TreeConfig

__all__ = ["ShardedExecutor", "ShardedSelection", "make_sharded_executor"]


class ShardedSelection:
    """Per-shard selection results, kept opaque: the pool threads this
    back into insert/backup, which route each part to its own child."""

    __slots__ = ("parts",)

    def __init__(self, parts: list):
        self.parts = parts


class ShardedExecutor:
    """D per-device child executors behind the single-arena protocol.

    `shards` is a list of (child, lo, n) runs: global rows [lo, lo + n)
    map to child rows [0, n).  For the top-level executor every child is
    fully mapped (n == child.G); a gathered sub-executor may pad each
    child to its own power of two (n < child.G) and the global width G
    to the pool's requested pow2 (rows past the last run are padding no
    shard owns — callers only read rows the active mask covers).
    """

    def __init__(self, cfg: TreeConfig, G: int, shards: list):
        self.cfg, self.G = cfg, int(G)
        self.shards = list(shards)

    # ---- partition helpers ----
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def devices(self) -> list:
        """Per-shard committed device (None for host-side children)."""
        return [getattr(c, "device", None) for c, _, _ in self.shards]

    def _locate(self, g: int):
        for child, lo, n in self.shards:
            if lo <= g < lo + n:
                return child, int(g) - lo
        raise IndexError(f"slot {g} outside every shard run")

    def _child_active(self, active) -> list:
        act = np.asarray(active, bool)
        out = []
        for child, lo, n in self.shards:
            a = np.zeros(child.G, bool)
            a[:n] = act[lo:lo + n]
            out.append(a)
        return out

    @staticmethod
    def _pad_rows(arr, lo: int, n: int, child_G: int, fill):
        """Slice global rows [lo, lo+n) and pad to the child width."""
        a = np.asarray(arr)
        out = np.full((child_G,) + a.shape[1:], fill, a.dtype)
        out[:n] = a[lo:lo + n]
        return out

    def _gather_rows(self, parts: list, fill=0) -> np.ndarray:
        """Reassemble per-child [child.G, ...] arrays into one [G, ...]
        array (padding rows no shard owns keep `fill`)."""
        p0 = np.asarray(parts[0])
        buf = np.full((self.G,) + p0.shape[1:], fill, p0.dtype)
        for (child, lo, n), part in zip(self.shards, parts):
            buf[lo:lo + n] = np.asarray(part)[:n]
        return buf

    # ---- device phases (fan out per shard, reassemble on host) ----
    def selection(self, active: np.ndarray, p: int):
        acts = self._child_active(active)
        # all shards' programs are queued before any host transfer:
        # child.selection on the device executors is async dispatch
        return ShardedSelection([
            child.selection(a, p)
            for (child, _, _), a in zip(self.shards, acts)])

    def sel_to_host(self, sel: ShardedSelection) -> dict:
        hosts = [child.sel_to_host(s)
                 for (child, _, _), s in zip(self.shards, sel.parts)]
        return {k: self._gather_rows([h[k] for h in hosts])
                for k in hosts[0]}

    def insert(self, active: np.ndarray, sel: ShardedSelection) -> np.ndarray:
        return self.insert_host(self.insert_dev(active, sel))

    def insert_dev(self, active: np.ndarray, sel: ShardedSelection) -> list:
        """Queue every shard's insert before any host transfer; the
        per-shard device id blocks come back as a list redeemed by
        insert_host (the overlap mode's staged device half)."""
        acts = self._child_active(active)
        return [child.insert_dev(a, s) for (child, _, _), a, s
                in zip(self.shards, acts, sel.parts)]

    def insert_host(self, parts: list) -> np.ndarray:
        return self._gather_rows(
            [child.insert_host(p)
             for (child, _, _), p in zip(self.shards, parts)], fill=NULL)

    def finalize(self, nodes, num_actions, terminal, prior_parent,
                 priors_fx):
        for child, lo, n in self.shards:
            child.finalize(
                self._pad_rows(nodes, lo, n, child.G, NULL),
                self._pad_rows(num_actions, lo, n, child.G, 0),
                self._pad_rows(terminal, lo, n, child.G, 0),
                self._pad_rows(prior_parent, lo, n, child.G, NULL),
                self._pad_rows(priors_fx, lo, n, child.G, 0))

    def backup(self, active, sel: ShardedSelection, sim_nodes, values_fx,
               alternating: bool, dropped=None):
        acts = self._child_active(active)
        for (child, lo, n), a, s in zip(self.shards, acts, sel.parts):
            child.backup(
                a, s,
                self._pad_rows(sim_nodes, lo, n, child.G, 0),
                self._pad_rows(values_fx, lo, n, child.G, 0),
                alternating,
                None if dropped is None
                else self._pad_rows(dropped, lo, n, child.G, 0))

    # ---- host-side slot access (route to the owning shard) ----
    def reset_slot(self, g: int, root_num_actions: int):
        child, r = self._locate(int(g))
        child.reset_slot(r, root_num_actions)

    def best_actions(self) -> np.ndarray:
        return self._gather_rows([c.best_actions()
                                  for c, _, _ in self.shards])

    def sizes(self) -> np.ndarray:
        return self._gather_rows([c.sizes() for c, _, _ in self.shards])

    def slot_snapshot(self, g: int) -> dict:
        child, r = self._locate(int(g))
        return child.slot_snapshot(r)

    def write_slot(self, g: int, arrays: dict):
        child, r = self._locate(int(g))
        child.write_slot(r, arrays)

    def block(self):
        for child, _, _ in self.shards:
            child.block()

    def release(self):
        for child, _, _ in self.shards:
            child.release()

    # ---- compaction (per-shard dense sub-arenas behind one session) ----
    def _shard_runs(self, slot_idx: np.ndarray):
        """Split a sorted global slot index into per-shard local runs."""
        idx = np.asarray(slot_idx, np.int64)
        for child, lo, n in self.shards:
            li = idx[(idx >= lo) & (idx < lo + n)] - lo
            if len(li):
                yield child, li

    def gather_sub(self, slot_idx: np.ndarray, Gc: int) -> "ShardedExecutor":
        subs, off = [], 0
        for child, li in self._shard_runs(slot_idx):
            c_gc = 1 << (len(li) - 1).bit_length()   # per-child pow2 pad
            subs.append((child.gather_sub(li, c_gc), off, len(li)))
            off += len(li)
        return ShardedExecutor(self.cfg, Gc, subs)

    def scatter_sub(self, sub: "ShardedExecutor", slot_idx: np.ndarray):
        parts = iter(sub.shards)
        for child, li in self._shard_runs(slot_idx):
            sub_child, _, _ = next(parts)
            child.scatter_sub(sub_child, li)

    def open_session(self, slot_idx: np.ndarray, Gc: int,
                     tracer=None, tid: int = 0):
        from repro.core.executor import CompactionSession
        return CompactionSession(self, slot_idx, Gc, tracer=tracer, tid=tid)

    # ---- single-tree compat surface ----
    def init(self, root_num_actions: int):
        return self.shards[0][0].init(root_num_actions)

    def get_tree(self, g: int = 0):
        child, r = self._locate(int(g))
        return child.get_tree(r)

    def set_tree(self, tree, g: int = 0):
        child, r = self._locate(int(g))
        child.set_tree(tree, r)

    def snapshot(self, tree) -> dict:
        return self.shards[0][0].snapshot(tree)

    def best_action(self, tree) -> int:
        return self.shards[0][0].best_action(tree)


def make_sharded_executor(cfg: TreeConfig, G: int, name: str,
                          n_shards: int,
                          devices: Optional[list] = None) -> ShardedExecutor:
    """Partition G slots into n_shards per-device child executors.

    Equal contiguous runs (G must divide evenly); shard d's child arena
    is committed to devices[d] — defaulting to
    launch.mesh.serving_devices, which wraps round-robin over the host's
    devices so any D works on any machine.  Reference children stay on
    host (the numpy oracle has no device to commit to) but still get the
    D-way partition, so the scheduler's placement logic is
    executor-agnostic."""
    n_shards = int(n_shards)
    if G % n_shards:
        raise ValueError(
            f"G={G} does not divide into n_shards={n_shards} equal shard "
            f"runs — pick G as a multiple of the shard count")
    if devices is None:
        from repro.launch.mesh import serving_devices
        devices = serving_devices(n_shards)
    if len(devices) < n_shards:
        raise ValueError(
            f"{len(devices)} devices for n_shards={n_shards}")
    from repro.core.executor import make_intree_executor
    gs = G // n_shards
    shards = []
    for d in range(n_shards):
        child = make_intree_executor(cfg, gs, name,
                                     devices=[devices[d]])
        shards.append((child, d * gs, gs))
    return ShardedExecutor(cfg, G, shards)
