"""Host-expansion engine (paper Alg. 2 step 3, batched across the arena).

The CPU half of Expansion — ST reads, 1-step env transitions, ST writes —
was a per-slot, per-worker Python loop over ``env.step``; fine for one
tree, the serving hot spot once G slots x p workers grow (ROADMAP).  This
module is the engine that removes it:

  mode="loop"    — the original per-worker loop (reference semantics).
  mode="vector"  — every pending expansion of every slot is flattened into
                   ONE [B] batch: one ``VectorEnv.step_batch`` call, one
                   ``num_actions_batch`` call, one duplicate-checked ST
                   write per slot (state_table.write's distinct-id assert
                   is the paper's §III-B invariant, now checked per batch).
                   Requires the env to implement envs.vector.VectorEnv.
  mode="pool"    — same flattening, but the batch is served by a process
                   pool of scalar-env workers (envs.vector.PoolVectorEnv)
                   — the paper's multi-worker CPU side, for envs without a
                   vectorized form.  Step and successor action counts are
                   fused into ONE pooled round-trip per superstep
                   (step_and_count_batch) so states are pickled once, not
                   twice.
  mode="auto"    — "vector" when the env supports it, else "loop".

All modes are bit-identical: the flattening preserves the loop's
(slot, worker, action) visit order, and step_batch implementations are
property-tested against scalar ``step`` (tests/test_vector_env.py); the
full cross-executor guarantee is pinned by tests/test_executor_matrix.py.

Asynchronous expansion (the overlap serving mode's host half):
``expand_submit`` does the flattening and — in pool mode — posts the env
batch to the worker processes WITHOUT waiting, returning a
PendingExpansion handle; ``expand_collect`` blocks on the posted chunks
and finishes the ST scatter.  ``expand`` is submit + collect back to
back, so the split is bit-identical to the blocking call and costs the
same single `batch_calls` round-trip.  Between submit and collect the
worker processes step their chunks while the caller's thread runs
another gang's Simulation / finalize / BackUp — that concurrency is the
whole point of the split (service.pool gang pipeline).  Modes without an
async env leg (loop / vector, or a tiny pooled batch) compute eagerly at
submit time: collect is then a cheap unwrap, and the overlap schedule
stays legal for every mode.

Both drivers consume this engine: TreeParallelMCTS feeds it one slot,
service.pool.ArenaPool feeds it every active slot of a superstep (and a
multi-bucket ServiceFrontend shares ONE engine across all its pools).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core import fixedpoint as fx
from repro.core.state_table import StateTable
from repro.core.tree import NULL
from repro.envs.vector import (
    PoolVectorEnv, has_async_step, has_fused_step, has_vector_env,
)
from repro.obs.metrics import NULL_REGISTRY
from repro.obs.trace import NULL_TRACER

EXPANSION_MODES = ("loop", "vector", "pool", "auto")


@dataclasses.dataclass
class HostExpansion:
    """Result of the host half of Expansion for one tree's superstep:
    1-step env transitions for every expanding worker, ST writes done,
    metadata queued for finalize, and the simulation batch rows."""

    sim_nodes: Any       # [p] i32 node each simulation runs from
    sim_states: Any      # [p, ...] states for SimulationBackend.evaluate
    fin_nodes: list      # inserted node ids (ragged)
    fin_na: list         # their legal-action counts
    fin_term: list       # their terminal flags
    prior_parents: list  # parents receiving prior rows (expand-all mode)
    prior_workers: list  # worker index whose sim state produced each prior

    def padded_finalize_args(self, K: int, p: int, Fp: int, priors) -> tuple:
        """Fixed-shape NULL-padded finalize arguments: every slot must
        contribute identical shapes to the arena finalize (the G=1 driver
        uses the same convention with a leading [1] axis)."""
        nodes = np.full(K, NULL, np.int32)
        na = np.zeros(K, np.int32)
        term = np.zeros(K, np.int32)
        k = len(self.fin_nodes)
        nodes[:k] = self.fin_nodes
        na[:k] = self.fin_na
        term[:k] = self.fin_term
        pp = np.full(p, NULL, np.int32)
        pf = np.zeros((p, Fp), np.int32)
        if priors is not None and self.prior_workers:
            pp[: len(self.prior_parents)] = self.prior_parents
            pf[: len(self.prior_workers)] = encode_prior_rows(
                priors, self.prior_workers, Fp)
        return nodes, na, term, pp, pf


def encode_prior_rows(priors, prior_workers, Fp: int) -> np.ndarray:
    """Select the expand-all workers' prior rows and pad to Fp lanes
    (Qm.16).  Priors are produced for the leaf states that expanded-all —
    sim node == leaf for those workers."""
    pr = np.asarray(priors)[prior_workers]
    padded = np.zeros((len(prior_workers), Fp), np.float32)
    padded[:, : pr.shape[1]] = pr
    return np.asarray(fx.encode(padded), np.int32)


def host_expand_phase(env, st: StateTable, sel: dict,
                      new_nodes: np.ndarray) -> HostExpansion:
    """ST reads, 1-step env transitions, ST writes (paper Alg. 2 step 3).

    Sync-free by the paper's §III-B invariant: every write targets a
    distinct freshly inserted node id.  `sel` is the host-side selection
    dict; `new_nodes` is the [p, Fp] id block from Node Insertion.

    This is the mode="loop" reference; ExpansionEngine's batched modes are
    bit-identical rewrites of this function across many slots at once.
    """
    p = sel["leaves"].shape[0]
    leaves = sel["leaves"]
    leaf_states = st.read(leaves)
    sim_nodes = leaves.copy()
    sim_states = leaf_states.copy()
    out = HostExpansion(sim_nodes, sim_states, [], [], [], [], [])
    for j in range(p):
        ea = int(sel["expand_action"][j])
        if ea == NULL:
            continue
        if ea == -2:  # expand-all (Gomoku benchmark mode)
            k = int(sel["n_insert"][j])
            states, nas, terms = [], [], []
            for a in range(k):
                s2, _, term = env.step(leaf_states[j], a)
                states.append(s2)
                nas.append(0 if term else env.num_actions(s2))
                terms.append(int(term))
            ids = new_nodes[j, :k]
            st.write(ids, np.stack(states))
            out.fin_nodes += list(ids)
            out.fin_na += nas
            out.fin_term += terms
            out.prior_parents.append(int(leaves[j]))
            out.prior_workers.append(j)
        else:
            s2, _, term = env.step(leaf_states[j], ea)
            nid = int(new_nodes[j, 0])
            st.write(np.array([nid]), s2[None])
            out.fin_nodes.append(nid)
            out.fin_na.append(0 if term else env.num_actions(s2))
            out.fin_term.append(int(term))
            out.sim_nodes[j] = nid
            out.sim_states[j] = s2
    return out


@dataclasses.dataclass
class PendingExpansion:
    """Handle for an in-flight ``expand_submit``: the flattening already
    happened (leaf reads, per-slot HostExpansion shells, [B] batch rows)
    and the env batch is either posted to the pool workers (``token``) or
    already computed (``eager`` / loop-mode ``out``).  One-shot:
    ``expand_collect`` consumes it."""

    per: Any            # [(g, st, sel, new_nodes, leaf_states, hx), ...]
    seg: Any            # [(pos, worker, expand_action, k), ...] batch rows
    out: dict           # {g: HostExpansion} (shells until collect scatters)
    token: Any = None   # venv PendingBatch when the IPC is in flight
    eager: Any = None   # (nxt, term, na_raw) when computed at submit
    counted: bool = False  # metrics already recorded (loop mode / expand())


class ExpansionEngine:
    """Batched host-expansion across every active slot of a superstep.

    ``expand(slots)`` takes ``[(g, st, sel, new_nodes), ...]`` — one entry
    per active slot, with that slot's StateTable, host-side selection dict
    and [p, Fp] inserted-id block — and returns ``{g: HostExpansion}``.
    """

    def __init__(self, env, mode: str = "loop", pool_workers: int = 2,
                 tracer=None, metrics=None):
        if mode not in EXPANSION_MODES:
            raise ValueError(f"expansion mode {mode!r}: one of "
                             f"{EXPANSION_MODES}")
        if mode == "auto":
            mode = "vector" if has_vector_env(env) else "loop"
        if mode == "vector" and not has_vector_env(env):
            raise ValueError(
                f"expansion='vector' needs step_batch/num_actions_batch on "
                f"{type(env).__name__}; use 'pool' (process-pool scalar "
                f"fallback) or 'loop'")
        self.env, self.mode = env, mode
        self._venv = (PoolVectorEnv(env, pool_workers) if mode == "pool"
                      else env)
        self.trace = NULL_TRACER if tracer is None else tracer
        reg = NULL_REGISTRY if metrics is None else metrics
        self._m_calls = reg.counter(
            "service_expand_batch_calls_total",
            "env batch round-trips issued by the expansion engine",
            mode=mode)
        self._m_rows = reg.counter(
            "service_expand_rows_total",
            "nodes expanded (env transitions) by the expansion engine",
            mode=mode)

    def expand(self, slots, tid: int = 0) -> dict:
        with self.trace.span("expand", cat="phase", tid=tid,
                             slots=len(slots) if hasattr(slots, "__len__")
                             else -1, mode=self.mode):
            if self.mode == "loop":
                out = {g: host_expand_phase(self.env, st, sel, nn)
                       for g, st, sel, nn in slots}
                rows = sum(len(hx.fin_nodes) for hx in out.values())
                # loop mode: one scalar env.step per row
                self._m_calls.inc(rows)
            else:
                pend = self._submit_batched(list(slots))
                out = self._collect_batched(pend)
                rows = sum(len(hx.fin_nodes) for hx in out.values())
                self._m_calls.inc(1 if rows else 0)
            self._m_rows.inc(rows)
            return out

    # -- asynchronous split (overlap mode's host half) ------------------
    def expand_submit(self, slots, tid: int = 0) -> "PendingExpansion":
        """Flatten every slot's pending expansions and — in pool mode —
        post the env batch to the workers without waiting.  Modes without
        an async leg compute eagerly here; either way the returned handle
        goes through expand_collect, and submit + collect is bit-identical
        to expand()."""
        with self.trace.span("expand-submit", cat="phase", tid=tid,
                             slots=len(slots) if hasattr(slots, "__len__")
                             else -1, mode=self.mode):
            if self.mode == "loop":
                out = {g: host_expand_phase(self.env, st, sel, nn)
                       for g, st, sel, nn in slots}
                rows = sum(len(hx.fin_nodes) for hx in out.values())
                self._m_calls.inc(rows)
                self._m_rows.inc(rows)
                return PendingExpansion(per=None, seg=None, out=out,
                                        counted=True)
            return self._submit_batched(list(slots))

    def expand_collect(self, pending: "PendingExpansion",
                       tid: int = 0) -> dict:
        """Redeem an expand_submit handle: block on the posted env batch
        (if one is in flight) and finish the finalize-metadata / ST
        scatter."""
        if pending.per is None:       # loop mode: computed at submit
            return pending.out
        with self.trace.span("expand-collect", cat="phase", tid=tid,
                             mode=self.mode):
            out = self._collect_batched(pending)
            if not pending.counted:
                rows = sum(len(hx.fin_nodes) for hx in out.values())
                self._m_calls.inc(1 if rows else 0)
                self._m_rows.inc(rows)
                pending.counted = True
            return out

    # -- one flattened batch over all slots' pending expansions ---------
    def _submit_batched(self, slots) -> "PendingExpansion":
        per, seg = [], []
        flat_states, flat_actions = [], []
        for pos, (g, st, sel, new_nodes) in enumerate(slots):
            leaves = sel["leaves"]
            leaf_states = st.read(leaves)
            hx = HostExpansion(leaves.copy(), leaf_states.copy(),
                               [], [], [], [], [])
            per.append((g, st, sel, new_nodes, leaf_states, hx))
            for j in range(leaves.shape[0]):
                ea = int(sel["expand_action"][j])
                if ea == NULL:
                    continue
                if ea == -2:  # expand-all: k rows of the same leaf state
                    k = int(sel["n_insert"][j])
                    for a in range(k):
                        flat_states.append(leaf_states[j])
                        flat_actions.append(a)
                    seg.append((pos, j, ea, k))
                else:
                    flat_states.append(leaf_states[j])
                    flat_actions.append(ea)
                    seg.append((pos, j, ea, 1))
        pend = PendingExpansion(per=per, seg=seg,
                                out={g: hx for (g, _, _, _, _, hx) in per})
        if not seg:  # saturated/terminal superstep: nothing to expand
            return pend
        states = np.stack(flat_states)
        actions = np.asarray(flat_actions, np.int64)
        if has_async_step(self._venv):
            # post once, wait at collect: the workers step their chunks
            # while the caller's thread runs another gang's superstep
            pend.token = self._venv.submit_batch(states, actions)
        elif has_fused_step(self._venv):
            # one round-trip: step + successor action counts together
            # (halves the per-superstep pickling of the pool fallback)
            nxt, _, term, na_raw = self._venv.step_and_count_batch(
                states, actions)
            pend.eager = (nxt, term, na_raw)
        else:
            nxt, _, term = self._venv.step_batch(states, actions)
            pend.eager = (nxt, term, self._venv.num_actions_batch(nxt))
        return pend

    def _collect_batched(self, pending: "PendingExpansion") -> dict:
        per, seg, out = pending.per, pending.seg, pending.out
        if not seg:
            return out
        if pending.token is not None:
            nxt, _, term, na_raw = self._venv.collect(pending.token)
            pending.token = None
        else:
            nxt, term, na_raw = pending.eager
        term = np.asarray(term, bool)
        na = np.where(term, 0, np.asarray(na_raw))

        # scatter per (slot, worker) segment; ONE duplicate-checked ST
        # write per slot (every id freshly allocated -> distinct)
        write_ids = [[] for _ in per]
        write_rows = [[] for _ in per]
        off = 0
        for pos, j, ea, k in seg:
            g, st, sel, new_nodes, leaf_states, hx = per[pos]
            rows = range(off, off + k)
            if ea == -2:
                ids = new_nodes[j, :k]
                write_ids[pos] += [int(i) for i in ids]
                write_rows[pos] += list(rows)
                hx.fin_nodes += list(ids)
                hx.fin_na += [int(na[r]) for r in rows]
                hx.fin_term += [int(term[r]) for r in rows]
                hx.prior_parents.append(int(sel["leaves"][j]))
                hx.prior_workers.append(j)
            else:
                nid = int(new_nodes[j, 0])
                write_ids[pos].append(nid)
                write_rows[pos].append(off)
                hx.fin_nodes.append(nid)
                hx.fin_na.append(int(na[off]))
                hx.fin_term.append(int(term[off]))
                hx.sim_nodes[j] = nid
                hx.sim_states[j] = nxt[off]
            off += k
        for pos, (g, st, _, _, _, _) in enumerate(per):
            if write_ids[pos]:
                st.write(np.asarray(write_ids[pos], np.int64),
                         nxt[write_rows[pos]])
        return out

    def close(self):
        if self.mode == "pool":
            self._venv.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
