"""UCT data structure (paper §III-A).

The paper decomposes the MCTS tree into the UCT (node/edge statistics,
accelerator SRAM) and the ST (environment states, host DRAM).  This module
is the UCT: a fixed-capacity struct-of-arrays holding every statistic the
in-tree operations touch, and nothing application-specific.

Layout notes (TPU adaptation of the paper's per-level SRAM banks):
  * all edge arrays are ``[X, Fp]`` with ``Fp`` = F rounded up to a power of
    two (<= 128) so a node's edge block never straddles a 128-lane VMEM row
    when flattened — see kernels/uct_select.py;
  * node ids are allocated in insertion order, which for the BSP execution
    model means ids are also grouped by superstep; the paper's level-bank
    partitioning is recovered through ``node_depth`` (used by the resource
    report, Table I analogue);
  * edge value sums (``edge_W``) and priors (``edge_P``) are stored in
    Qm.16 fixed point (paper §IV-C) so every in-tree update is an integer
    add — exact, commutative, and bit-reproducible across the numpy oracle,
    the jit batched ops, and the Pallas kernels.

Capacity is allocated for ``X`` nodes (the paper statically allocates banks
for a full F-ary tree of height D; with F=36/D=5 a full tree is ~60M nodes
against X=48K actually reachable, so we keep the X cap — the full-tree
allocation is an FPGA synthesis constraint with no TPU benefit).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fx

NULL = -1  # sentinel child / node index


def pad_fanout(f: int) -> int:
    """Round F up to a power of two <= 128 (VMEM row alignment)."""
    if f > 128:
        raise NotImplementedError(f"fanout {f} > 128: multi-row edge blocks not implemented")
    p = 1
    while p < f:
        p <<= 1
    return p


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Static configuration of the in-tree machinery.

    vl_mode:
      * "wu"       — WU-UCT visit-count virtual loss [Liu et al., ICLR'20]:
                     incomplete-visit counters enter both uct terms.
      * "constant" — constant virtual loss [Chaslot et al. '08]: a fixed
                     penalty per in-flight worker is subtracted from the
                     edge weight (paper Alg. 1 line 5 semantics).
    score_fn:
      * "uct"  — Eq. 1 of the paper.
      * "puct" — AlphaZero-style prior-weighted variant (the paper's Gomoku
                 benchmark [9] uses a policy-value DNN; PUCT is its native
                 score).
    leaf_mode:
      * "partial"    — a node is a selection leaf while any child is
                       unexpanded (paper §II-A definition).
      * "unexpanded" — a node is a leaf until its first expansion; used with
                       expand_all=True (Gomoku benchmark expands all F
                       children at once, paper §V-A).
    """

    X: int
    F: int
    D: int
    beta: float = 1.0
    vl_mode: str = "wu"
    vl_const: float = 1.0
    score_fn: str = "uct"
    leaf_mode: str = "partial"
    expand_all: bool = False

    def __post_init__(self):
        assert self.vl_mode in ("wu", "constant"), self.vl_mode
        assert self.score_fn in ("uct", "puct"), self.score_fn
        assert self.leaf_mode in ("partial", "unexpanded"), self.leaf_mode
        assert self.X >= 2 and self.F >= 1 and self.D >= 1

    @property
    def Fp(self) -> int:
        return pad_fanout(self.F)

    @property
    def vl_const_fx(self) -> int:
        return fx.encode_scalar(self.vl_const)

    def sram_bytes(self) -> dict:
        """Table I analogue: bytes of accelerator memory per component."""
        edge_arrays = 4 + (1 if self.score_fn == "puct" else 0)  # child,N,W,VL(,P)
        node_arrays = 5  # node_N, node_O, num_expanded, num_actions, node_depth
        per_edge = 4 * edge_arrays
        per_node = 4 * node_arrays
        return {
            "edge_bytes": self.X * self.Fp * per_edge,
            "node_bytes": self.X * per_node,
            "log_table_bytes": 4 * (self.X + 2),
            "total_bytes": self.X * self.Fp * per_edge + self.X * per_node + 4 * (self.X + 2),
        }


def bucket_key(cfg: TreeConfig) -> tuple:
    """Canonical arena-pool bucket of a config (service frontend routing).

    Two configs share a pool iff every field that can change a slot's bit
    evolution matches.  The only padding that is semantics-free is the
    fanout: ``F`` enters the device programs solely through the ``Fp``
    edge-array layout (scoring masks by per-node ``num_actions`` from the
    env, and insert's provisional ``num_actions = F`` is overwritten by
    finalize before any read), so F=3 and F=4 requests share an Fp=4
    arena.  ``X`` and ``D`` look like shape parameters but are semantic —
    X caps the per-superstep insertion budget and the move-saturation
    check, D caps selection depth — so padding either would break the
    frontend's bit-identity contract with a dedicated single-config
    service.
    """
    return (cfg.X, cfg.Fp, cfg.D, cfg.beta, cfg.vl_mode, cfg.vl_const,
            cfg.score_fn, cfg.leaf_mode, cfg.expand_all)


def canonical_config(cfg: TreeConfig) -> TreeConfig:
    """The pool-side representative of ``cfg``'s bucket: fanout padded to
    the Fp lane width, everything semantic untouched.  ``bucket_key`` of
    the result equals ``bucket_key(cfg)``."""
    return dataclasses.replace(cfg, F=cfg.Fp)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class UCTree:
    """The UCT — everything the accelerator touches, nothing else."""

    child: Any         # [X, Fp] i32  child node id or NULL
    edge_N: Any        # [X, Fp] i32  completed visits through edge
    edge_W: Any        # [X, Fp] i32  Qm.16 sum of backed-up values
    edge_VL: Any       # [X, Fp] i32  in-flight (virtual-loss) count
    edge_P: Any        # [X, Fp] i32  Qm.16 prior (puct only; zeros otherwise)
    node_N: Any        # [X] i32      completed visits of node
    node_O: Any        # [X] i32      in-flight visits of node (WU-UCT O_s)
    num_expanded: Any  # [X] i32
    num_actions: Any   # [X] i32      legal-action count (<= F)
    node_depth: Any    # [X] i32
    terminal: Any      # [X] i32      1 if state is terminal (never internal)
    size: Any          # [] i32       next free node id
    root: Any          # [] i32
    log_table: Any     # [2X+4] f32   ln(n) table shared by all backends

    @property
    def X(self) -> int:
        return self.child.shape[0]

    @property
    def Fp(self) -> int:
        return self.child.shape[1]


def make_log_table(x: int) -> np.ndarray:
    """ln(n) lookup shared by every backend.

    Computed once in f64 then cast, so numpy-oracle / jit-jax / Pallas all
    read bit-identical values (libm ``log`` implementations may differ by an
    ulp between backends; a shared table removes that hazard — the TPU
    version of the paper's 'deterministic fixed-point compare' argument).
    Sized 2X+4 and index-clamped: node visit counts can exceed X when the
    tree is capacity-saturated but workers keep iterating.
    """
    n = np.arange(2 * x + 4, dtype=np.float64)
    with np.errstate(divide="ignore"):
        t = np.log(n)
    t[0] = 0.0
    return t.astype(np.float32)


def init_tree(cfg: TreeConfig, root_num_actions: int | None = None, xp=jnp) -> UCTree:
    """Fresh tree with a single root node (id 0)."""
    X, Fp = cfg.X, cfg.Fp
    i32 = xp.int32
    z_e = xp.zeros((X, Fp), dtype=i32)
    na = cfg.F if root_num_actions is None else int(root_num_actions)
    num_actions = xp.zeros((X,), dtype=i32)
    if xp is np:
        child = np.full((X, Fp), NULL, dtype=np.int32)
        num_actions = num_actions.copy()
        num_actions[0] = na
    else:
        child = xp.full((X, Fp), NULL, dtype=i32)
        num_actions = num_actions.at[0].set(na)
    return UCTree(
        child=child,
        edge_N=z_e,
        edge_W=z_e,
        edge_VL=z_e,
        edge_P=z_e,
        node_N=xp.zeros((X,), dtype=i32),
        node_O=xp.zeros((X,), dtype=i32),
        num_expanded=xp.zeros((X,), dtype=i32),
        num_actions=num_actions,
        node_depth=xp.zeros((X,), dtype=i32),
        terminal=xp.zeros((X,), dtype=i32),
        size=xp.asarray(1, dtype=i32) if xp is jnp else np.int32(1),
        root=xp.asarray(0, dtype=i32) if xp is jnp else np.int32(0),
        log_table=xp.asarray(make_log_table(X)),
    )


def to_numpy(tree: UCTree) -> UCTree:
    return jax.tree.map(np.asarray, tree)


def to_jax(tree: UCTree) -> UCTree:
    return jax.tree.map(jnp.asarray, tree)


# --------------------------------------------------------------------------
# Tree arena: G independent UCTrees stacked into one pytree (service layer)
# --------------------------------------------------------------------------
#
# Every leaf gains a leading [G] axis, so the whole arena is still a UCTree
# and the batched in-tree ops of intree.py apply per slot under jax.vmap
# (see intree.select_arena etc.).  The log table is identical across slots
# but stacked anyway: a uniform layout keeps vmap in_axes trivial, and at
# f32[G, 2X+4] the duplication is noise next to the edge arrays.

def stack_trees(trees: list) -> UCTree:
    """Stack G single trees into one arena pytree (leading [G] axis)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_arena(cfg: TreeConfig, G: int, root_num_actions: int | None = None) -> UCTree:
    """Arena of G fresh single-root trees."""
    one = init_tree(cfg, root_num_actions)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), one)


def arena_slot(arena: UCTree, g: int) -> UCTree:
    """Extract slot g as a single UCTree view."""
    return jax.tree.map(lambda a: a[g], arena)


def arena_set_slot(arena: UCTree, g: int, tree: UCTree) -> UCTree:
    """Functionally write a single tree into slot g."""
    return jax.tree.map(lambda a, v: a.at[g].set(v), arena, tree)


def where_trees(mask, new: UCTree, old: UCTree) -> UCTree:
    """Per-slot select between two arenas: mask[g] picks new slot g.

    Used by the arena ops to make idle slots no-ops: the vmapped op runs on
    every slot (uniform device program) and this post-select discards the
    updates of inactive ones.
    """
    def pick(a, b):
        m = jnp.reshape(jnp.asarray(mask), mask.shape + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree.map(pick, new, old)
