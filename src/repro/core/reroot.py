"""Subtree-reusing Tree Flush (beyond-paper).

The paper flushes the entire tree at each MCTS step ("the best child
becomes the new root while the rest of the tree are flushed") because the
FPGA statically banks SRAM per level — its own future-work section names
dynamic bank management as an open problem.  On TPU the UCT is just
arrays, so we can re-root: extract the chosen child's subtree, compact
node ids, and keep all of its statistics — every simulation spent below
the chosen action carries over to the next step.

Host-side numpy (runs at the step boundary, off the hot superstep path).
"""

from __future__ import annotations

import numpy as np

from repro.core.tree import NULL, TreeConfig, UCTree, init_tree


def reroot(cfg: TreeConfig, snap: dict, new_root: int):
    """snap: numpy snapshot of a UCTree (executor.snapshot()).
    Returns (new UCTree arrays as numpy dict, old_to_new index map)."""
    X = cfg.X
    child = snap["child"]
    # BFS from new_root
    order = [int(new_root)]
    seen = {int(new_root)}
    for n in order:
        for c in child[n]:
            c = int(c)
            if c != NULL and c not in seen:
                seen.add(c)
                order.append(c)
    old2new = np.full(X, NULL, np.int32)
    for new_id, old_id in enumerate(order):
        old2new[old_id] = new_id

    fresh = {k: np.array(v) for k, v in snap.items()
             if k not in ("size", "root", "log_table")}
    out = {}
    for k in ("edge_N", "edge_W", "edge_VL", "edge_P",
              "num_expanded", "num_actions", "terminal",
              "node_N", "node_O"):
        dst = np.zeros_like(fresh[k])
        dst[: len(order)] = fresh[k][order]
        out[k] = dst
    ch = np.full_like(fresh["child"], NULL)
    remapped = np.where(child[order] != NULL,
                        old2new[np.clip(child[order], 0, X - 1)], NULL)
    ch[: len(order)] = remapped
    out["child"] = ch
    nd = np.zeros_like(fresh["node_depth"])
    nd[: len(order)] = fresh["node_depth"][order] - int(
        fresh["node_depth"][new_root])
    out["node_depth"] = nd
    out["size"] = np.int32(len(order))
    out["root"] = np.int32(0)
    out["log_table"] = np.array(snap["log_table"])
    return out, old2new


def reroot_tree(cfg: TreeConfig, snap: dict, new_root: int, xp):
    arrays, old2new = reroot(cfg, snap, new_root)
    t = UCTree(**{k: xp.asarray(v) for k, v in arrays.items()})
    return t, old2new
