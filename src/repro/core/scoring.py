"""Edge scoring (paper Eq. 1 + virtual-loss variants), backend-generic.

One scoring routine shared — verbatim — by the sequential numpy oracle,
the batched jit ops and the Pallas kernel reference.  All inputs are
integers (counts + Qm.16 sums); all transcendental inputs come from the
shared ln-table; every float op used (convert / divide / sqrt / add /
multiply-by-pow2 / round) is IEEE-754 correctly rounded, so numpy-f32 and
jax-f32 produce bit-identical scores and therefore identical argmax
decisions.  This is how the paper's "exact same outputs as a CPU-only
system" claim survives vectorization.

Shapes: edge inputs are ``[..., Fp]``; node inputs broadcast as ``[..., 1]``.
Returns int32 fixed-point scores ``[..., Fp]`` where invalid lanes are
FX_NEG_INF and never-visited edges are FX_FORCE_EXPLORE (uct) so they win
any comparison against real scores (<= FX_MAX).
"""

from __future__ import annotations

import numpy as np

from repro.core import fixedpoint as fx
from repro.core.tree import NULL, TreeConfig


def edge_scores_fx(
    cfg: TreeConfig,
    *,
    child,        # [..., Fp] i32
    edge_N,       # [..., Fp] i32
    edge_W,       # [..., Fp] i32 (Qm.16)
    edge_VL,      # [..., Fp] i32
    edge_P,       # [..., Fp] i32 (Qm.16)
    node_N,       # [..., 1]  i32
    node_O,       # [..., 1]  i32
    num_actions,  # [..., 1]  i32
    log_table=None,  # [2X+4] f32 (omit iff log_ns given)
    xp=np,
    lane=None,       # optional precomputed lane-index array [..., Fp]
                     # (Pallas kernels pass a 2-D broadcasted iota: 1-D iota
                     #  does not lower on TPU)
    log_ns=None,     # optional precomputed ln(ns) [..., 1] f32 (kernels do
                     #  the scalar table load themselves)
):
    i32, f32 = xp.int32, xp.float32
    Fp = child.shape[-1]
    if lane is None:
        lane = xp.arange(Fp, dtype=i32)
    valid = (lane < num_actions) & (child != NULL)

    if cfg.vl_mode == "wu":
        ne = edge_N + edge_VL                    # N̄ = N + O (in-flight)
        ns = node_N + node_O
    else:
        ne = edge_N
        ns = node_N
    ns = xp.minimum(ns, i32(2 * cfg.X + 3))      # log-table bound (tree.py)

    ne_safe = xp.maximum(ne, i32(1)).astype(f32)
    if log_ns is None:
        log_ns = xp.take(log_table, ns, axis=0)  # [..., 1] f32 (shared table)

    if cfg.score_fn == "uct":
        q = (edge_W.astype(f32) * f32(fx.FX_INV_SCALE)) / ne_safe
        u = f32(cfg.beta) * xp.sqrt(log_ns / ne_safe)
        base = fx.encode(q + u, xp=xp)
        base = xp.where(ne == 0, fx.FX_FORCE_EXPLORE, base)
    else:  # puct: Q + c * P * sqrt(Ns) / (1 + Ne); Q := 0 when unvisited
        q = (edge_W.astype(f32) * f32(fx.FX_INV_SCALE)) / ne_safe
        q = xp.where(ne == 0, f32(0.0), q)
        sqrt_ns = xp.sqrt(ns.astype(f32))
        p_f = edge_P.astype(f32) * f32(fx.FX_INV_SCALE)
        u = f32(cfg.beta) * p_f * sqrt_ns / (f32(1.0) + ne.astype(f32))
        base = fx.encode(q + u, xp=xp)

    if cfg.vl_mode == "constant":
        # Paper Alg. 1 line 5: uct(s, s_hat) -= VL, applied per in-flight
        # worker; exact integer arithmetic in the Qm.16 domain.
        base = base - i32(cfg.vl_const_fx) * edge_VL

    return xp.where(valid, base, fx.FX_NEG_INF)


def argmax_first(scores_fx, xp=np):
    """First-maximum argmax over the last axis (deterministic tie-break,
    matching both np.argmax and jnp.argmax semantics)."""
    return xp.argmax(scores_fx, axis=-1).astype(xp.int32)


def is_leaf(cfg: TreeConfig, *, num_expanded, num_actions, terminal, depth, xp=np):
    """Selection-leaf predicate (paper §II-A; see TreeConfig.leaf_mode)."""
    if cfg.leaf_mode == "partial":
        open_node = num_expanded < num_actions
    else:
        open_node = num_expanded == 0
    return open_node | (terminal != 0) | (depth >= cfg.D) | (num_actions == 0)
