"""Gomoku (paper benchmark b): 6x6 board, 4-in-row, F = 36, D = 5, X = 48K.

Mirrors the paper's second benchmark [9] (junxiaosong/AlphaZero_Gomoku):
small board, n-in-row win, the Expansion phase expands *all* legal children
of a selected leaf, and the Simulation phase is policy-value inference
(see envs/policy_net.py) or a random playout fallback.

State is 108 f32 words = 432 bytes — byte-identical ST traffic to the
paper's reported Gomoku state size.

Layout: [0] player-to-move (+1/-1)  [1] terminal  [2] winner (+1/-1/0)
        [3:39] board cells (row-major; 0 empty, +1, -1); [39:108] pad.

Action index `a` at a state = the a-th empty cell in row-major order
(stable per state, matching the driver's action-indexing contract).
"""

from __future__ import annotations

import numpy as np

_BOARD = 6
_CELLS = _BOARD * _BOARD
_WIN = 4
_N = 108  # 432 bytes


class GomokuEnv:
    state_shape = (_N,)
    state_dtype = np.float32
    max_actions = _CELLS

    def initial_state(self, seed: int = 0) -> np.ndarray:
        s = np.zeros(_N, np.float32)
        s[0] = 1.0
        return s

    @staticmethod
    def board(state: np.ndarray) -> np.ndarray:
        return state[3 : 3 + _CELLS].reshape(_BOARD, _BOARD)

    def num_actions(self, state: np.ndarray) -> int:
        if state[1]:
            return 0
        return int(np.sum(state[3 : 3 + _CELLS] == 0))

    @staticmethod
    def legal_cells(state: np.ndarray) -> np.ndarray:
        return np.flatnonzero(state[3 : 3 + _CELLS] == 0)

    def step(self, state: np.ndarray, a: int):
        s = state.copy()
        assert not s[1]
        cells = self.legal_cells(s)
        cell = int(cells[a])
        player = s[0]
        s[3 + cell] = player
        r, c = divmod(cell, _BOARD)
        if _wins(self.board(s), r, c, player):
            s[1], s[2] = 1.0, player
            reward = 1.0          # from the mover's perspective
        elif len(cells) == 1:     # board full -> draw
            s[1], s[2] = 1.0, 0.0
            reward = 0.0
        else:
            reward = 0.0
        s[0] = -player
        return s, float(reward), bool(s[1])

    # ---- VectorEnv (envs.vector): batched twin, bit-identical to step ----

    def num_actions_batch(self, states: np.ndarray) -> np.ndarray:
        states = np.asarray(states, np.float32)
        empties = (states[:, 3 : 3 + _CELLS] == 0).sum(1)
        return np.where(states[:, 1] != 0, 0, empties).astype(np.int64)

    def step_batch(self, states: np.ndarray, actions: np.ndarray):
        s = np.asarray(states, np.float32).copy()
        a = np.asarray(actions).astype(np.int64)
        B = len(s)
        assert not s[:, 1].any(), "step_batch on terminal state"
        board = s[:, 3 : 3 + _CELLS]            # view: writes land in s
        empty = board == 0
        n_empty = empty.sum(1)
        assert ((a >= 0) & (a < n_empty)).all(), "illegal action in batch"
        # the a-th empty cell in row-major order, per row
        target = empty & (np.cumsum(empty, axis=1) == (a + 1)[:, None])
        cell = target.argmax(1)
        player = s[:, 0].copy()
        rows = np.arange(B)
        board[rows, cell] = player
        r, c = np.divmod(cell, _BOARD)
        win = _wins_batch(board.reshape(B, _BOARD, _BOARD), r, c, player)
        draw = ~win & (n_empty == 1)            # move filled the last cell
        terminal = win | draw
        s[:, 1] = terminal
        s[:, 2] = np.where(win, player, 0.0)
        s[:, 0] = -player
        reward = np.where(win, 1.0, 0.0)        # mover's perspective
        return s, reward, terminal


def _wins_batch(boards: np.ndarray, r: np.ndarray, c: np.ndarray,
                player: np.ndarray) -> np.ndarray:
    """Batched _wins: contiguous-run length through the placed cell per
    direction, counted with a bounded offset sweep (runs longer than _WIN
    still win, exactly as the scalar while-loop)."""
    B = len(r)
    rows = np.arange(B)
    win = np.zeros(B, bool)
    for dr, dc in ((0, 1), (1, 0), (1, 1), (1, -1)):
        n = np.ones(B, np.int64)
        for sgn in (1, -1):
            alive = np.ones(B, bool)
            for i in range(1, _WIN):
                rr = r + sgn * dr * i
                cc = c + sgn * dc * i
                inb = (rr >= 0) & (rr < _BOARD) & (cc >= 0) & (cc < _BOARD)
                val = boards[rows, np.clip(rr, 0, _BOARD - 1),
                             np.clip(cc, 0, _BOARD - 1)]
                alive &= inb & (val == player)
                n += alive
        win |= n >= _WIN
    return win


def _wins(board: np.ndarray, r: int, c: int, player: float) -> bool:
    for dr, dc in ((0, 1), (1, 0), (1, 1), (1, -1)):
        n = 1
        for sgn in (1, -1):
            rr, cc = r + sgn * dr, c + sgn * dc
            while 0 <= rr < _BOARD and 0 <= cc < _BOARD and board[rr, cc] == player:
                n += 1
                rr += sgn * dr
                cc += sgn * dc
        if n >= _WIN:
            return True
    return False


class GomokuRolloutBackend:
    """Random-playout evaluator; returns value from the perspective of the
    player to move at the given state (AlphaZero convention, used with
    alternating_signs=True in the driver)."""

    def __init__(self, env: GomokuEnv, seed: int = 0):
        self.env = env
        self.rng = np.random.RandomState(seed)

    def evaluate(self, states: np.ndarray):
        vals = np.zeros(len(states), np.float32)
        for i, s in enumerate(states):
            vals[i] = self._value(s)
        return vals, None

    def _value(self, state: np.ndarray) -> float:
        me = state[0]
        if state[1]:
            w = state[2]
            return 0.0 if w == 0 else (1.0 if w == me else -1.0)
        s = state
        while not s[1]:
            k = self.env.num_actions(s)
            s, _, _ = self.env.step(s, int(self.rng.randint(k)))
        w = s[2]
        return 0.0 if w == 0 else (1.0 if w == me else -1.0)
