"""Device-evaluable environment / simulation twins.

The fused K-superstep dispatch (repro.core.fused) keeps select → insert
→ simulate → finalize → backup on device for K supersteps; that is only
possible when the environment's transition function and the simulation
backend's value function are expressible as jittable JAX ops that are
**bit-identical** to their host twins — the whole executor matrix rests
on exact equality, so "close enough in f32" is not good enough.

Protocol (duck-typed, mirrors envs.vector.has_vector_env):

  env.step_device(states, actions) -> (next_states, terminal)
      Total function over [B, *state_shape] f32 states and [B] i32
      actions (callers pass clamped actions for masked-off rows; the
      results of those rows are discarded).  No rewards — rewards are
      only consumed at move commits, which always happen on host.
  env.num_actions_device(states) -> i32[B]
  env.resolvable_device(states, actions) -> bool[B]   (optional)
      True where the transition CAN be resolved on device.  Rows that
      come back False force the fused loop to escape to the host
      expansion path.  Absent means "always resolvable".
  sim.evaluate_device(states) -> f32[B]
      Values only; priors force the host path (expand_all pools never
      enter the fused loop).

The only nontrivial piece is 64-bit integer hashing under a 32-bit JAX
build: ``hash24_device`` emulates the splitmix-style mix of
envs.bandit_tree._hash on (hi, lo) uint32 pairs — wrap-around adds with
explicit carry, 32x32→64 multiplies via 16-bit limbs — so it is
bit-equal to the numpy uint64 twin with or without JAX_ENABLE_X64.
"""

from __future__ import annotations

import numpy as np

_MASK16 = 0xFFFF
_MASK24 = 0xFFFFFF

# splitmix64 constants of envs.bandit_tree._hash, split into (hi, lo)
_C1_HI, _C1_LO = 0x9E3779B9, 0x7F4A7C15
_C2_HI, _C2_LO = 0xBF58476D, 0x1CE4E5B9


def _u32(x):
    import jax.numpy as jnp

    if isinstance(x, int):          # x32 rejects python ints >= 2^31
        x = np.uint32(x)
    return jnp.asarray(x).astype(jnp.uint32)


def _add64(a, b):
    """(hi, lo) + (hi, lo) mod 2^64 with explicit carry."""
    hi_a, lo_a = a
    hi_b, lo_b = b
    lo = lo_a + lo_b                       # uint32 wraps mod 2^32
    carry = (lo < lo_a).astype(lo.dtype)
    return hi_a + hi_b + carry, lo


def _shl64(a, k: int):
    """(hi, lo) << k for a static 0 < k < 32."""
    hi, lo = a
    return (hi << k) | (lo >> (32 - k)), lo << k


def _mul32x32(a, b):
    """uint32 x uint32 -> full 64-bit product as (hi, lo), via 16-bit
    limbs so no intermediate exceeds 32 bits."""
    a0, a1 = a & _MASK16, a >> 16
    b0, b1 = b & _MASK16, b >> 16
    p00 = a0 * b0
    p10 = a1 * b0
    mid = a0 * b1 + (p00 >> 16) + (p10 & _MASK16)  # bounded by 2^32 - 1
    lo = (mid << 16) | (p00 & _MASK16)
    hi = a1 * b1 + (p10 >> 16) + (mid >> 16)
    return hi, lo


def _mul64(a, b):
    """Low 64 bits of (hi, lo) * (hi, lo)."""
    hi_a, lo_a = a
    hi_b, lo_b = b
    hi, lo = _mul32x32(lo_a, lo_b)
    return hi + lo_a * hi_b + hi_a * lo_b, lo


def hash24_device(h, a):
    """Bit-exact device twin of envs.bandit_tree._hash / _hash_batch.

    ``h`` and ``a`` are integer arrays whose values fit in uint32 (the
    env guarantees 24-bit hashes and small action codes).  Returns i32
    masked to 24 bits, equal element-for-element to the numpy uint64
    version in both x32 and x64 JAX modes.
    """
    import jax.numpy as jnp

    h = _u32(h)
    a = _u32(a)
    zero = jnp.zeros_like(h)
    t = _add64(_add64((zero, a), (_u32(_C1_HI), _u32(_C1_LO))),
               _shl64((zero, h), 6))
    x = (t[0], h ^ t[1])
    x = _mul64(x, (_u32(_C2_HI), _u32(_C2_LO)))
    lo = x[1] ^ ((x[1] >> 31) | (x[0] << 1))   # (x ^= x >> 31), low word
    return (lo & _u32(_MASK24)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# capability probes (duck-typed, like envs.vector.has_vector_env)
# ---------------------------------------------------------------------------

def has_device_env(env) -> bool:
    """True when the env can resolve expansions inside a fused dispatch."""
    return (callable(getattr(env, "step_device", None))
            and callable(getattr(env, "num_actions_device", None)))


def has_device_sim(sim) -> bool:
    """True when the backend has a jittable value leg (values only —
    prior-producing backends keep the host path)."""
    return callable(getattr(sim, "evaluate_device", None))


def has_async_sim(sim) -> bool:
    """True when the backend exposes the non-blocking submit/collect
    split (repro.sim: SimServer, CachedSimBackend): submit enqueues rows
    into the serving admission window and returns a ticket; collect
    redeems it.  Callers holding several pools' rows submit them ALL
    before collecting, so a microbatching server coalesces across pools
    even when cross-pool fusion is off."""
    return (callable(getattr(sim, "submit", None))
            and callable(getattr(sim, "collect", None)))


def resolvable_device(env, states, actions):
    """bool[B] — rows whose transition the device twin can resolve.
    Envs without the hook are fully resolvable."""
    import jax.numpy as jnp

    hook = getattr(env, "resolvable_device", None)
    if hook is None:
        return jnp.ones(np.shape(actions), bool)
    return hook(states, actions)
