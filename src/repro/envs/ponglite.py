"""PongLite — deterministic Atari-Pong-like environment (paper benchmark a).

Matches the paper's Pong workload *shape*: fanout F = 6, tree height limit
D = 9, X = 56K nodes, and a 256-byte environment state (the paper reports
256 B/state ST entries for Pong) — here 64 f32 words, of which the first 8
are live physics and the rest zero padding so the ST traffic per operation
is byte-identical to the paper's.

Physics: a ball bounces in a unit box; the agent's paddle moves on the
right wall with 6 discrete velocity actions (Atari Pong's action set size).
Reward +1 on paddle hit, -1 on miss (episode ends), 0 otherwise.
Deterministic given (state, action).
"""

from __future__ import annotations

import numpy as np

# state layout: [0] ball_x [1] ball_y [2] vel_x [3] vel_y
#               [4] paddle_y [5] t [6] terminal [7] score ; [8:64] pad
_N = 64
_PAD_BYTES = _N * 4  # 256 B, as in the paper


class PongLiteEnv:
    state_shape = (_N,)
    state_dtype = np.float32
    max_actions = 6

    # paddle velocity per action id (Atari: NOOP/FIRE/UP/DOWN/UPFIRE/DOWNFIRE)
    _PADDLE_V = np.array([0.0, 0.0, 0.08, -0.08, 0.16, -0.16], np.float32)

    def __init__(self, max_t: int = 200):
        self.max_t = max_t

    def initial_state(self, seed: int) -> np.ndarray:
        rng = np.random.RandomState(seed)
        s = np.zeros(_N, np.float32)
        s[0], s[1] = 0.3, rng.uniform(0.2, 0.8)
        ang = rng.uniform(-0.9, 0.9)
        s[2], s[3] = 0.06, 0.06 * np.sin(ang)
        s[4] = 0.5
        return s

    def num_actions(self, state: np.ndarray) -> int:
        return 0 if state[6] else 6

    def step(self, state: np.ndarray, a: int):
        s = state.copy()
        assert not s[6]
        s[4] = np.clip(s[4] + self._PADDLE_V[a], 0.1, 0.9)
        s[0] += s[2]
        s[1] += s[3]
        if s[1] < 0.0 or s[1] > 1.0:            # top/bottom bounce
            s[3] = -s[3]
            s[1] = np.clip(s[1], 0.0, 1.0)
        if s[0] < 0.0:                           # left wall bounce
            s[2] = -s[2]
            s[0] = 0.0
        reward = 0.0
        if s[0] >= 1.0:                          # reaches paddle plane
            if abs(s[1] - s[4]) < 0.12:          # hit
                reward = 1.0
                s[7] += 1
                s[2] = -abs(s[2])
                s[3] += 0.25 * (s[1] - s[4])     # english
                s[0] = 1.0
            else:                                # miss -> terminal
                reward = -1.0
                s[6] = 1.0
        s[5] += 1
        if s[5] >= self.max_t:
            s[6] = 1.0
        return s, float(reward), bool(s[6])

    # ---- VectorEnv (envs.vector): batched twin, bit-identical to step ----
    # All arithmetic stays in f32 exactly as the scalar path (same ops on
    # the same dtype in the same order), so the results match bit for bit.

    def num_actions_batch(self, states: np.ndarray) -> np.ndarray:
        return np.where(np.asarray(states, np.float32)[:, 6] != 0, 0, 6
                        ).astype(np.int64)

    def step_batch(self, states: np.ndarray, actions: np.ndarray):
        s = np.asarray(states, np.float32).copy()
        a = np.asarray(actions).astype(np.int64)
        assert not s[:, 6].any(), "step_batch on terminal state"
        assert ((a >= 0) & (a < 6)).all(), "illegal action in batch"
        s[:, 4] = np.clip(s[:, 4] + self._PADDLE_V[a], 0.1, 0.9)
        s[:, 0] += s[:, 2]
        s[:, 1] += s[:, 3]
        bounce = (s[:, 1] < 0.0) | (s[:, 1] > 1.0)   # top/bottom bounce
        s[bounce, 3] = -s[bounce, 3]
        s[bounce, 1] = np.clip(s[bounce, 1], 0.0, 1.0)
        left = s[:, 0] < 0.0                         # left wall bounce
        s[left, 2] = -s[left, 2]
        s[left, 0] = 0.0
        plane = s[:, 0] >= 1.0                       # reaches paddle plane
        hit = plane & (np.abs(s[:, 1] - s[:, 4]) < 0.12)
        miss = plane & ~hit
        s[hit, 7] += 1
        s[hit, 2] = -np.abs(s[hit, 2])
        s[hit, 3] += np.float32(0.25) * (s[hit, 1] - s[hit, 4])  # english
        s[hit, 0] = 1.0
        s[miss, 6] = 1.0
        reward = np.where(hit, 1.0, np.where(miss, -1.0, 0.0))
        s[:, 5] += 1
        s[s[:, 5] >= self.max_t, 6] = 1.0
        return s, reward, s[:, 6] != 0
