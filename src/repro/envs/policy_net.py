"""Policy-value network for Gomoku (the paper's DNN Simulation backend).

Small AlphaZero-style convnet in raw JAX (no flax): two 3x3 conv blocks,
a policy head (1x1 conv -> 36 logits) and a value head (tanh scalar).
Used by NNSimBackend (batch-p inference = the paper's "batch-1 DNN
inference per worker" aggregated across workers — the batching the paper's
Fig. 5 says would increase its speedup further) and by the self-play
training example.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_BOARD = 6
_CELLS = _BOARD * _BOARD


def init_params(rng: jax.Array, channels: int = 32) -> dict:
    k = jax.random.split(rng, 6)
    he = jax.nn.initializers.he_normal()
    return {
        "c1": he(k[0], (3, 3, 2, channels), jnp.float32),
        "c2": he(k[1], (3, 3, channels, channels), jnp.float32),
        "pol": he(k[2], (1, 1, channels, 2), jnp.float32),
        "pol_w": he(k[3], (2 * _CELLS, _CELLS), jnp.float32),
        "val_w1": he(k[4], (channels * _CELLS, 64), jnp.float32),
        "val_w2": he(k[5], (64, 1), jnp.float32),
    }


def apply(params: dict, boards: jax.Array):
    """boards: [B, 6, 6] canonicalized (+1 = player to move).
    Returns (values [B], logits [B, 36])."""
    x = jnp.stack([(boards > 0).astype(jnp.float32),
                   (boards < 0).astype(jnp.float32)], axis=-1)  # [B,6,6,2]
    dn = jax.lax.conv_dimension_numbers(x.shape, params["c1"].shape,
                                        ("NHWC", "HWIO", "NHWC"))
    x = jax.nn.relu(jax.lax.conv_general_dilated(
        x, params["c1"], (1, 1), "SAME", dimension_numbers=dn))
    x = jax.nn.relu(jax.lax.conv_general_dilated(
        x, params["c2"], (1, 1), "SAME", dimension_numbers=dn))
    pol = jax.lax.conv_general_dilated(
        x, params["pol"], (1, 1), "SAME", dimension_numbers=dn)
    logits = pol.reshape(pol.shape[0], -1) @ params["pol_w"]
    v = jax.nn.relu(x.reshape(x.shape[0], -1) @ params["val_w1"])
    values = jnp.tanh(v @ params["val_w2"])[:, 0]
    return values, logits


@functools.partial(jax.jit, static_argnums=())
def _infer(params, boards):
    return apply(params, boards)


class NNSimBackend:
    """DNN inference simulation backend (paper Gomoku benchmark).

    evaluate() returns values from the player-to-move perspective and
    priors over *legal actions in legal order* (the driver's action
    indexing), padded to max_actions.
    """

    def __init__(self, env, params: dict):
        self.env, self.params = env, params

    def evaluate(self, states: np.ndarray):
        B = len(states)
        boards = states[:, 3 : 3 + _CELLS].reshape(B, _BOARD, _BOARD)
        to_move = states[:, 0:1]
        canon = boards * to_move[:, :, None]
        values, logits = jax.device_get(
            _infer(self.params, jnp.asarray(canon, jnp.float32)))
        vals = np.array(values, np.float32)  # copy: device_get is read-only
        pri = np.zeros((B, self.env.max_actions), np.float32)
        for i in range(B):
            if states[i, 1]:  # terminal: exact value, no priors
                w, me = states[i, 2], states[i, 0]
                vals[i] = 0.0 if w == 0 else (1.0 if w == me else -1.0)
                continue
            legal = np.flatnonzero(states[i, 3 : 3 + _CELLS] == 0)
            z = logits[i, legal]
            z = np.exp(z - z.max())
            pri[i, : len(legal)] = z / z.sum()
        return vals, pri
