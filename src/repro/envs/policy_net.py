"""Policy-value network for Gomoku (the paper's DNN Simulation backend).

Small AlphaZero-style convnet in raw JAX (no flax): two 3x3 conv blocks,
a policy head (1x1 conv -> 36 logits) and a value head (tanh scalar).
Used by NNSimBackend (batch-p inference = the paper's "batch-1 DNN
inference per worker" aggregated across workers — the batching the paper's
Fig. 5 says would increase its speedup further) and by the self-play
training example.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_BOARD = 6
_CELLS = _BOARD * _BOARD


def init_params(rng: jax.Array, channels: int = 32) -> dict:
    k = jax.random.split(rng, 6)
    he = jax.nn.initializers.he_normal()
    return {
        "c1": he(k[0], (3, 3, 2, channels), jnp.float32),
        "c2": he(k[1], (3, 3, channels, channels), jnp.float32),
        "pol": he(k[2], (1, 1, channels, 2), jnp.float32),
        "pol_w": he(k[3], (2 * _CELLS, _CELLS), jnp.float32),
        "val_w1": he(k[4], (channels * _CELLS, 64), jnp.float32),
        "val_w2": he(k[5], (64, 1), jnp.float32),
    }


def apply(params: dict, boards: jax.Array):
    """boards: [B, 6, 6] canonicalized (+1 = player to move).
    Returns (values [B], logits [B, 36])."""
    x = jnp.stack([(boards > 0).astype(jnp.float32),
                   (boards < 0).astype(jnp.float32)], axis=-1)  # [B,6,6,2]
    dn = jax.lax.conv_dimension_numbers(x.shape, params["c1"].shape,
                                        ("NHWC", "HWIO", "NHWC"))
    x = jax.nn.relu(jax.lax.conv_general_dilated(
        x, params["c1"], (1, 1), "SAME", dimension_numbers=dn))
    x = jax.nn.relu(jax.lax.conv_general_dilated(
        x, params["c2"], (1, 1), "SAME", dimension_numbers=dn))
    pol = jax.lax.conv_general_dilated(
        x, params["pol"], (1, 1), "SAME", dimension_numbers=dn)
    logits = pol.reshape(pol.shape[0], -1) @ params["pol_w"]
    v = jax.nn.relu(x.reshape(x.shape[0], -1) @ params["val_w1"])
    values = jnp.tanh(v @ params["val_w2"])[:, 0]
    return values, logits


@functools.partial(jax.jit, static_argnums=())
def _infer(params, boards):
    return apply(params, boards)


class NNSimBackend:
    """DNN inference simulation backend (paper Gomoku benchmark).

    evaluate() returns values from the player-to-move perspective and
    priors over *legal actions in legal order* (the driver's action
    indexing), padded to max_actions.

    The forward pass is exposed as a non-blocking ``dispatch``/
    ``finalize`` split (mirroring core.expand's submit/collect): dispatch
    starts the jitted forward and returns the in-flight device arrays
    without a host sync; finalize device_gets them and runs the host
    post-processing.  ``evaluate`` is dispatch + finalize back to back —
    repro.sim.server.SimServer uses the split to keep device inference in
    flight across microbatch assembly.
    """

    def __init__(self, env, params: dict):
        self.env, self.params = env, params

    def dispatch(self, states: np.ndarray):
        """Start the forward for a batch; JAX dispatch is async, so this
        returns immediately with the in-flight (values, logits) arrays."""
        B = len(states)
        boards = states[:, 3 : 3 + _CELLS].reshape(B, _BOARD, _BOARD)
        canon = boards * states[:, 0:1][:, :, None]
        return _infer(self.params, jnp.asarray(canon, jnp.float32))

    def finalize(self, token, states: np.ndarray):
        """Block on a dispatched forward and post-process: terminal rows
        get their exact game value (no priors); the rest get a masked
        softmax over legal cells, compacted into legal order.

        One vectorized numpy pass over all rows (the historical per-row
        Python loop was O(B) on the hot simulation path).  Each row's
        result is a pure function of that row alone — masked max, exp,
        and a fixed-width 36-cell row sum — which is the property the
        serving layer's bit-identity guarantees rest on (batch
        composition, caching, and padding can never change a row's
        result).  Values are unchanged from the loop; priors agree up to
        summation-grouping ulps (the loop summed the gathered legal
        values, this sums the fixed-width masked row)."""
        values, logits = jax.device_get(token)
        B = len(states)
        cells = states[:, 3 : 3 + _CELLS]
        term = states[:, 1] != 0
        legal = (cells == 0) & ~term[:, None]
        z = np.where(legal, logits, np.float32(-np.inf))
        m = z.max(axis=1)
        mm = np.where(np.isfinite(m), m, np.float32(0.0))
        ez = np.exp(z - mm[:, None])          # exact 0.0 at masked cells
        denom = ez.sum(axis=1)
        soft = ez / np.where(denom > 0, denom, np.float32(1.0))[:, None]
        pri = np.zeros((B, self.env.max_actions), np.float32)
        # scatter each legal cell's mass to its legal-order column
        pos = np.cumsum(cells == 0, axis=1) - 1
        ii, jj = np.nonzero(legal)
        pri[ii, pos[ii, jj]] = soft[ii, jj]
        w, me = states[:, 2], states[:, 0]
        tv = np.where(w == 0, np.float32(0.0),
                      np.where(w == me, np.float32(1.0), np.float32(-1.0)))
        vals = np.where(term, tv, values).astype(np.float32, copy=False)
        return vals, pri

    def evaluate(self, states: np.ndarray):
        return self.finalize(self.dispatch(states), states)
