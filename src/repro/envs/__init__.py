from repro.envs.bandit_tree import BanditTreeEnv, BanditValueBackend
from repro.envs.ponglite import PongLiteEnv
from repro.envs.gomoku import GomokuEnv, GomokuRolloutBackend
from repro.envs.device import has_device_env, has_device_sim
from repro.envs.vector import (
    PoolVectorEnv, VectorEnv, has_fused_step, has_vector_env,
)

__all__ = ["BanditTreeEnv", "BanditValueBackend", "PongLiteEnv", "GomokuEnv",
           "GomokuRolloutBackend", "PoolVectorEnv", "VectorEnv",
           "has_device_env", "has_device_sim",
           "has_fused_step", "has_vector_env"]
