from repro.envs.bandit_tree import BanditTreeEnv, BanditValueBackend
from repro.envs.ponglite import PongLiteEnv
from repro.envs.gomoku import GomokuEnv, GomokuRolloutBackend

__all__ = ["BanditTreeEnv", "BanditValueBackend", "PongLiteEnv", "GomokuEnv",
           "GomokuRolloutBackend"]
