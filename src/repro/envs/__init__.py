from repro.envs.bandit_tree import BanditTreeEnv, BanditValueBackend
from repro.envs.ponglite import PongLiteEnv
from repro.envs.gomoku import GomokuEnv, GomokuRolloutBackend
from repro.envs.vector import (
    PoolVectorEnv, VectorEnv, has_fused_step, has_vector_env,
)

__all__ = ["BanditTreeEnv", "BanditValueBackend", "PongLiteEnv", "GomokuEnv",
           "GomokuRolloutBackend", "PoolVectorEnv", "VectorEnv",
           "has_fused_step", "has_vector_env"]
