"""Synthetic deterministic tree environment for tests and microbenchmarks.

A reproducible F-ary decision tree whose terminal rewards come from an
integer hash of the action history.  Deterministic, hashable, trivially
cheap — ideal for property tests of the in-tree machinery (the paper's
correctness claims are about the tree, not the game).
"""

from __future__ import annotations

import numpy as np

_M64 = (1 << 64) - 1


def _hash(h: int, a: int) -> int:
    """splitmix-style mix; result masked to 24 bits so it round-trips
    exactly through the f32 ST entry."""
    x = (int(h) ^ ((int(a) + 0x9E3779B97F4A7C15 + (int(h) << 6)) & _M64)) & _M64
    x = (x * 0xBF58476D1CE4E5B9) & _M64
    x ^= x >> 31
    return int(x & 0xFFFFFF)


def _hash_batch(h: np.ndarray, a) -> np.ndarray:
    """Vectorized _hash: uint64 wrap-around arithmetic is exactly the
    scalar's mod-2^64 masking, element for element."""
    h = np.asarray(h).astype(np.uint64)
    a = np.broadcast_to(np.asarray(a), h.shape).astype(np.uint64)
    x = h ^ (a + np.uint64(0x9E3779B97F4A7C15) + (h << np.uint64(6)))
    x = x * np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(31)
    return (x & np.uint64(0xFFFFFF)).astype(np.int64)


class BanditValueBackend:
    """Deterministic per-state simulation backend.

    The value is a pure function of the state's hash field, so evaluate()
    is invariant to batch composition and ordering — exactly what the
    service-layer equivalence tests need: a fused multi-tree batch must
    produce the same values as per-tree batches (a shared-RNG rollout
    backend would not, since interleaving changes its stream).
    """

    def evaluate(self, states):
        # NOTE: the op sequence is deliberately (exact integer subtract in
        # f32, then ONE rounded multiply).  A divide would be rewritten to
        # multiply-by-reciprocal by XLA's simplifier, and multiply-then-
        # subtract gets FMA-contracted on CPU — both break the bit
        # equality with evaluate_device that the fused dispatch's oracle
        # tests demand.  (m - 1000) is exact: |m - 1000| < 2^11.
        h = np.asarray(states)[:, 1].astype(np.int64)
        m = (_hash_batch(h, 4242) % 2000).astype(np.float32)
        return (m - np.float32(1000.0)) * np.float32(1e-3), None

    def evaluate_device(self, states):
        """Jittable twin of evaluate() — bit-equal values (see NOTE)."""
        import jax.numpy as jnp

        from repro.envs.device import hash24_device

        h = states[..., 1].astype(jnp.int32)
        m = (hash24_device(h, 4242) % 2000).astype(jnp.float32)
        return (m - jnp.float32(1000.0)) * jnp.float32(1e-3)


class BanditTreeEnv:
    """State: f32[8] = [depth, hash, terminal, n_actions, 0...]."""

    state_shape = (8,)
    state_dtype = np.float32

    def __init__(self, fanout: int = 6, terminal_depth: int = 12,
                 varying_fanout: bool = False):
        self.F = fanout
        self.max_actions = fanout
        self.terminal_depth = terminal_depth
        self.varying_fanout = varying_fanout

    def _na(self, h: int, depth: int) -> int:
        if depth >= self.terminal_depth:
            return 0
        if self.varying_fanout:
            return 1 + _hash(h, 7777) % self.F
        return self.F

    def initial_state(self, seed: int) -> np.ndarray:
        s = np.zeros(8, np.float32)
        h = _hash(seed, 12345)
        s[1] = h
        s[3] = self._na(h, 0)
        return s

    def num_actions(self, state: np.ndarray) -> int:
        return int(state[3])

    def step(self, state: np.ndarray, a: int):
        d, h = int(state[0]), int(state[1])
        assert 0 <= a < self._na(h, d), (a, self._na(h, d))
        h2, d2 = _hash(h, a), d + 1
        term = d2 >= self.terminal_depth
        s = np.zeros(8, np.float32)
        s[0], s[1] = d2, h2
        s[2] = float(term)
        s[3] = self._na(h2, d2)
        # dense shaped reward in [-0.5, 0.5], deterministic per transition
        r = (_hash(h2, 999) % 1000) / 1000.0 - 0.5
        return s, float(r), term

    # ---- VectorEnv (envs.vector): batched twin, bit-identical to step ----

    def _na_batch(self, h: np.ndarray, depth: np.ndarray) -> np.ndarray:
        if self.varying_fanout:
            na = 1 + _hash_batch(h, 7777) % self.F
        else:
            na = np.full(len(h), self.F, np.int64)
        return np.where(depth >= self.terminal_depth, 0, na)

    def num_actions_batch(self, states: np.ndarray) -> np.ndarray:
        return np.asarray(states)[:, 3].astype(np.int64)

    def step_batch(self, states: np.ndarray, actions: np.ndarray):
        states = np.asarray(states, np.float32)
        a = np.asarray(actions).astype(np.int64)
        d = states[:, 0].astype(np.int64)
        h = states[:, 1].astype(np.int64)
        na = self._na_batch(h, d)
        assert ((a >= 0) & (a < na)).all(), "illegal action in batch"
        h2, d2 = _hash_batch(h, a), d + 1
        term = d2 >= self.terminal_depth
        s = np.zeros((len(a), 8), np.float32)
        s[:, 0] = d2
        s[:, 1] = h2
        s[:, 2] = term
        s[:, 3] = self._na_batch(h2, d2)
        r = (_hash_batch(h2, 999) % 1000) / 1000.0 - 0.5
        return s, r, term

    # ---- device twins (repro.envs.device): jittable, bit-identical ----
    #
    # No rewards on device: the fused dispatch only resolves expansions;
    # rewards are consumed at move commits, which always run on host.
    # All fields round-trip exactly through f32 (depth < 2^24, 24-bit
    # hash, 0/1 terminal flag, n_actions <= F).

    def _na_device(self, h, depth):
        import jax.numpy as jnp

        from repro.envs.device import hash24_device

        if self.varying_fanout:
            na = 1 + hash24_device(h, 7777) % self.F
        else:
            na = jnp.full(h.shape, self.F, jnp.int32)
        return jnp.where(depth >= self.terminal_depth, 0, na)

    def num_actions_device(self, states):
        import jax.numpy as jnp

        return states[..., 3].astype(jnp.int32)

    def step_device(self, states, actions):
        import jax.numpy as jnp

        from repro.envs.device import hash24_device

        d = states[..., 0].astype(jnp.int32)
        h = states[..., 1].astype(jnp.int32)
        a = actions.astype(jnp.int32)
        h2, d2 = hash24_device(h, a), d + 1
        term = d2 >= self.terminal_depth
        s = jnp.zeros_like(states)
        s = s.at[..., 0].set(d2.astype(states.dtype))
        s = s.at[..., 1].set(h2.astype(states.dtype))
        s = s.at[..., 2].set(term.astype(states.dtype))
        s = s.at[..., 3].set(self._na_device(h2, d2).astype(states.dtype))
        return s, term
