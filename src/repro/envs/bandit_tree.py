"""Synthetic deterministic tree environment for tests and microbenchmarks.

A reproducible F-ary decision tree whose terminal rewards come from an
integer hash of the action history.  Deterministic, hashable, trivially
cheap — ideal for property tests of the in-tree machinery (the paper's
correctness claims are about the tree, not the game).
"""

from __future__ import annotations

import numpy as np

_M64 = (1 << 64) - 1


def _hash(h: int, a: int) -> int:
    """splitmix-style mix; result masked to 24 bits so it round-trips
    exactly through the f32 ST entry."""
    x = (int(h) ^ ((int(a) + 0x9E3779B97F4A7C15 + (int(h) << 6)) & _M64)) & _M64
    x = (x * 0xBF58476D1CE4E5B9) & _M64
    x ^= x >> 31
    return int(x & 0xFFFFFF)


def _hash_batch(h: np.ndarray, a) -> np.ndarray:
    """Vectorized _hash: uint64 wrap-around arithmetic is exactly the
    scalar's mod-2^64 masking, element for element."""
    h = np.asarray(h).astype(np.uint64)
    a = np.broadcast_to(np.asarray(a), h.shape).astype(np.uint64)
    x = h ^ (a + np.uint64(0x9E3779B97F4A7C15) + (h << np.uint64(6)))
    x = x * np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(31)
    return (x & np.uint64(0xFFFFFF)).astype(np.int64)


class BanditValueBackend:
    """Deterministic per-state simulation backend.

    The value is a pure function of the state's hash field, so evaluate()
    is invariant to batch composition and ordering — exactly what the
    service-layer equivalence tests need: a fused multi-tree batch must
    produce the same values as per-tree batches (a shared-RNG rollout
    backend would not, since interleaving changes its stream).
    """

    def evaluate(self, states):
        vals = np.array(
            [(_hash(int(s[1]), 4242) % 2000) / 1000.0 - 1.0 for s in states],
            np.float32)
        return vals, None


class BanditTreeEnv:
    """State: f32[8] = [depth, hash, terminal, n_actions, 0...]."""

    state_shape = (8,)
    state_dtype = np.float32

    def __init__(self, fanout: int = 6, terminal_depth: int = 12,
                 varying_fanout: bool = False):
        self.F = fanout
        self.max_actions = fanout
        self.terminal_depth = terminal_depth
        self.varying_fanout = varying_fanout

    def _na(self, h: int, depth: int) -> int:
        if depth >= self.terminal_depth:
            return 0
        if self.varying_fanout:
            return 1 + _hash(h, 7777) % self.F
        return self.F

    def initial_state(self, seed: int) -> np.ndarray:
        s = np.zeros(8, np.float32)
        h = _hash(seed, 12345)
        s[1] = h
        s[3] = self._na(h, 0)
        return s

    def num_actions(self, state: np.ndarray) -> int:
        return int(state[3])

    def step(self, state: np.ndarray, a: int):
        d, h = int(state[0]), int(state[1])
        assert 0 <= a < self._na(h, d), (a, self._na(h, d))
        h2, d2 = _hash(h, a), d + 1
        term = d2 >= self.terminal_depth
        s = np.zeros(8, np.float32)
        s[0], s[1] = d2, h2
        s[2] = float(term)
        s[3] = self._na(h2, d2)
        # dense shaped reward in [-0.5, 0.5], deterministic per transition
        r = (_hash(h2, 999) % 1000) / 1000.0 - 0.5
        return s, float(r), term

    # ---- VectorEnv (envs.vector): batched twin, bit-identical to step ----

    def _na_batch(self, h: np.ndarray, depth: np.ndarray) -> np.ndarray:
        if self.varying_fanout:
            na = 1 + _hash_batch(h, 7777) % self.F
        else:
            na = np.full(len(h), self.F, np.int64)
        return np.where(depth >= self.terminal_depth, 0, na)

    def num_actions_batch(self, states: np.ndarray) -> np.ndarray:
        return np.asarray(states)[:, 3].astype(np.int64)

    def step_batch(self, states: np.ndarray, actions: np.ndarray):
        states = np.asarray(states, np.float32)
        a = np.asarray(actions).astype(np.int64)
        d = states[:, 0].astype(np.int64)
        h = states[:, 1].astype(np.int64)
        na = self._na_batch(h, d)
        assert ((a >= 0) & (a < na)).all(), "illegal action in batch"
        h2, d2 = _hash_batch(h, a), d + 1
        term = d2 >= self.terminal_depth
        s = np.zeros((len(a), 8), np.float32)
        s[:, 0] = d2
        s[:, 1] = h2
        s[:, 2] = term
        s[:, 3] = self._na_batch(h2, d2)
        r = (_hash_batch(h2, 999) % 1000) / 1000.0 - 0.5
        return s, r, term
