"""VectorEnv — batched host-environment stepping for the expansion engine.

The paper's CPU side runs p workers' expansion/simulation concurrently
while the FPGA serves the in-tree phases; our host analogue is the
expansion engine (core.expand), which flattens every pending expansion of
every tree slot into ONE [B] batch.  This module defines the contract the
engine consumes and the process-pool fallback for environments that have
no vectorized form:

  VectorEnv      — protocol: step [B] states x [B] actions in one call and
                   count legal actions for [B] states in one call.  The
                   three in-repo envs (bandit_tree / gomoku / ponglite)
                   implement it natively with numpy array programs that
                   are bit-identical to their scalar ``step`` (property-
                   tested in tests/test_vector_env.py).
  PoolVectorEnv  — wraps a scalar Environment behind the same protocol by
                   chunking the batch over a process pool of workers each
                   holding an env replica — the multi-worker CPU side of
                   the paper, for envs where a numpy rewrite is not worth
                   it.  Deterministic: chunk boundaries depend only on
                   (B, workers) and results are concatenated in order.

Fused stepping: the expansion engine always needs the legal-action count
of every stepped state, and running that as step_batch THEN
num_actions_batch costs a pooled env two IPC round-trips per superstep —
the next states are pickled back to the workers that just produced them.
``step_and_count_batch`` is the optional protocol extension that fuses
both into one round-trip (each worker counts the action of the state it
just stepped, in-process); the engine uses it when present
(``has_fused_step``), and PoolVectorEnv implements it.  Bit-identical to
the two-call form for any deterministic env.

Asynchronous stepping: the overlap serving mode (service.pool gang
pipeline) wants the pooled env batch IN FLIGHT while the main thread
finishes another gang's superstep, so the fused call splits into
``submit_batch`` (states pickled and posted to the workers ONCE, returns
immediately with a handle) and ``collect`` (block on the posted chunks
and concatenate).  ``step_and_count_batch`` is now exactly
``collect(submit_batch(...))`` — the blocking compatibility wrapper —
so the split costs one `batch_calls` round-trip like the fused call it
replaces, and is bit-identical to it (pinned in tests/test_vector_env).
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class VectorEnv(Protocol):
    """Batched twin of core.mcts.Environment.

    Implementations must be bit-identical to looping the scalar ``step``
    / ``num_actions`` over the batch — the expansion engine relies on it
    for the loop/vector bit-equivalence the service promises.
    """

    def step_batch(self, states: np.ndarray, actions: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """[B, ...] states x [B] actions -> (next_states [B, ...],
        rewards [B] f64, terminal [B] bool)."""
        ...

    def num_actions_batch(self, states: np.ndarray) -> np.ndarray:
        """[B, ...] states -> [B] legal-action counts (0 when terminal)."""
        ...


def has_vector_env(env) -> bool:
    """True when `env` natively implements the VectorEnv protocol."""
    return callable(getattr(env, "step_batch", None)) and callable(
        getattr(env, "num_actions_batch", None))


def has_fused_step(venv) -> bool:
    """True when `venv` implements the optional fused
    ``step_and_count_batch`` extension (one round-trip for step +
    legal-action count — PoolVectorEnv's IPC halving)."""
    return callable(getattr(venv, "step_and_count_batch", None))


def has_async_step(venv) -> bool:
    """True when `venv` implements the non-blocking ``submit_batch`` /
    ``collect`` split of the fused step (the overlap serving mode's
    host-side pipelining hook)."""
    return (callable(getattr(venv, "submit_batch", None))
            and callable(getattr(venv, "collect", None)))


# --------------------------------------------------------------------------
# Process-pool fallback (paper's multi-worker CPU side)
# --------------------------------------------------------------------------

_WORKER_ENV = None  # per-process env replica (set by the pool initializer)


def _pool_init(env):
    global _WORKER_ENV
    _WORKER_ENV = env


def _pool_step_chunk(payload):
    states, actions = payload
    nxt, rew, term = [], [], []
    for s, a in zip(states, actions):
        s2, r, t = _WORKER_ENV.step(s, int(a))
        nxt.append(s2)
        rew.append(r)
        term.append(t)
    return (np.stack(nxt), np.asarray(rew, np.float64),
            np.asarray(term, bool))


def _pool_na_chunk(states):
    return np.asarray([_WORKER_ENV.num_actions(s) for s in states], np.int64)


def _pool_step_na_chunk(payload):
    """Fused chunk: step AND count the successor's legal actions in the
    worker, so the successor states never round-trip through pickling
    just to be counted."""
    states, actions = payload
    nxt, rew, term, na = [], [], [], []
    for s, a in zip(states, actions):
        s2, r, t = _WORKER_ENV.step(s, int(a))
        nxt.append(s2)
        rew.append(r)
        term.append(t)
        na.append(_WORKER_ENV.num_actions(s2))
    return (np.stack(nxt), np.asarray(rew, np.float64),
            np.asarray(term, bool), np.asarray(na, np.int64))


class PendingBatch:
    """Handle for an in-flight submit_batch: the posted chunk futures, or
    the already-computed result when the batch was small enough to step
    inline (no IPC).  One-shot: collect() consumes it."""

    __slots__ = ("futures", "result")

    def __init__(self, futures=None, result=None):
        self.futures = futures
        self.result = result


class PoolVectorEnv:
    """Scalar env behind the VectorEnv protocol via a process pool.

    Workers are spawned lazily on first use (so constructing the engine
    is free) and each holds its own env replica, rebuilt from the pickled
    env by the pool initializer; batches are chunked into at most
    `workers` contiguous pieces whose results are concatenated in
    submission order — the output is bit-identical to a scalar loop for
    any deterministic env.  Call close() (or use as a context manager)
    when done; idle pools also die with the parent process.
    """

    def __init__(self, env, workers: int = 2):
        self.env = env
        self.workers = max(1, int(workers))
        self._pool = None
        # batched round-trips served (fused counts once — the engine's
        # per-superstep IPC halving is observable here)
        self.batch_calls = 0

    def _ensure_pool(self):
        if self._pool is None:
            import multiprocessing
            from concurrent.futures import ProcessPoolExecutor
            # spawn, not fork: the parent typically has jax threads live,
            # and forking a multithreaded process can deadlock
            self._pool = ProcessPoolExecutor(
                max_workers=self.workers, initializer=_pool_init,
                initargs=(self.env,),
                mp_context=multiprocessing.get_context("spawn"))
        return self._pool

    def _chunks(self, n: int) -> list:
        bounds = np.linspace(0, n, self.workers + 1).astype(int)
        return [(int(a), int(b)) for a, b in zip(bounds[:-1], bounds[1:])
                if b > a]

    def step_batch(self, states, actions):
        states = np.asarray(states)
        actions = np.asarray(actions)
        spans = self._chunks(len(states))
        self.batch_calls += 1
        if len(spans) <= 1:  # tiny batch: skip the IPC round-trip
            _pool_init(self.env)
            out = [_pool_step_chunk((states, actions))]
        else:
            out = list(self._ensure_pool().map(
                _pool_step_chunk,
                [(states[a:b], actions[a:b]) for a, b in spans]))
        return (np.concatenate([o[0] for o in out]),
                np.concatenate([o[1] for o in out]),
                np.concatenate([o[2] for o in out]))

    def num_actions_batch(self, states):
        states = np.asarray(states)
        spans = self._chunks(len(states))
        self.batch_calls += 1
        if len(spans) <= 1:
            _pool_init(self.env)
            return _pool_na_chunk(states)
        out = list(self._ensure_pool().map(
            _pool_na_chunk, [states[a:b] for a, b in spans]))
        return np.concatenate(out)

    def submit_batch(self, states, actions) -> PendingBatch:
        """Post the fused step + legal-action-count batch to the workers
        WITHOUT waiting: the states are pickled and posted once, right
        here, and the returned handle is redeemed later with collect().
        One `batch_calls` round-trip, exactly like the blocking fused
        call — the worker processes step their chunks while the caller's
        thread does other work (the overlap serving mode's host half)."""
        states = np.asarray(states)
        actions = np.asarray(actions)
        spans = self._chunks(len(states))
        self.batch_calls += 1
        if len(spans) <= 1:  # tiny batch: step inline, nothing in flight
            _pool_init(self.env)
            return PendingBatch(result=_pool_step_na_chunk((states, actions)))
        pool = self._ensure_pool()
        return PendingBatch(futures=[
            pool.submit(_pool_step_na_chunk, (states[a:b], actions[a:b]))
            for a, b in spans])

    def collect(self, pending: PendingBatch):
        """Block on a submit_batch handle and concatenate its chunks:
        (next_states, rewards, terminal, num_actions).  Posts nothing —
        the states already crossed the IPC boundary at submit time."""
        if pending.result is not None:
            out = [pending.result]
        else:
            out = [f.result() for f in pending.futures]
        return tuple(np.concatenate([o[i] for o in out]) for i in range(4))

    def step_and_count_batch(self, states, actions):
        """Fused step + legal-action count: ONE pooled round-trip instead
        of step_batch followed by num_actions_batch (which pickles the
        freshly produced successor states back to the workers).  Returns
        (next_states, rewards, terminal, num_actions) — bit-identical to
        the two-call form.  Compatibility wrapper over the non-blocking
        submit_batch/collect split (same chunking, same single post)."""
        return self.collect(self.submit_batch(states, actions))

    def close(self):
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
