"""Fault tolerance and straggler mitigation policies.

Two layers:

1. **Training** (LM substrate): checkpoint/restart via
   distributed.checkpoint (atomic, elastic across meshes) + deterministic
   data order (data pipeline is seeded by step index, so replay after
   restart consumes the identical batches).

2. **MCTS serving** (the paper's system): the BSP superstep itself is the
   natural fault boundary.  Virtual loss makes a *dropped* worker safe:
   its VL is simply recovered by a compensating backup with V drawn from
   the current edge mean (or discarded wholesale at the next Tree Flush).
   BSPFaultPolicy implements the paper-consistent policy:

     * straggler mitigation: a superstep commits when `quorum` of p
       simulation results arrived before `timeout`; missing workers'
       backups are replaced by VL-recovery-only updates (edge stats get
       their virtual loss removed, no reward contribution) — equivalent
       to the worker never having been dispatched, so the UCT invariants
       (VL==0, O==0 at quiescence) still hold;
     * worker failure: same mechanism, permanently masking the worker slot
       (elastic p).

HeartbeatMonitor is the host-side liveness tracker used by the launcher;
in this single-host container it is exercised by tests with synthetic
clocks.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import fixedpoint as fx


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-worker liveness from heartbeat timestamps."""

    n_workers: int
    timeout_s: float = 5.0

    def __post_init__(self):
        self.last_beat = np.zeros(self.n_workers, dtype=np.float64)
        self.alive = np.ones(self.n_workers, dtype=bool)

    def beat(self, worker: int, now: float | None = None):
        self.last_beat[worker] = time.time() if now is None else now

    def sweep(self, now: float | None = None) -> np.ndarray:
        now = time.time() if now is None else now
        self.alive = (now - self.last_beat) <= self.timeout_s
        return self.alive

    def mark_dead(self, worker: int):
        self.alive[worker] = False


class BSPFaultPolicy:
    """Commit rule for a Tree-Parallel MCTS superstep under stragglers.

    Given per-worker completion flags, produce the (values, mask) pair for
    the backup phase: masked workers get a VL-recovery-only backup
    (value contribution 0 and edge_N not incremented — implemented by the
    driver re-running backup with a worker mask).
    """

    def __init__(self, p: int, quorum: float = 0.75):
        self.p = p
        self.quorum = quorum

    def commit_mask(self, done: np.ndarray) -> tuple[bool, np.ndarray]:
        """(should_commit, mask). should_commit is False until quorum."""
        frac = float(done.mean()) if len(done) else 0.0
        return frac >= self.quorum, done.copy()

    def masked_values(self, values: np.ndarray, mask: np.ndarray):
        """Values for backup: masked-out workers contribute 0 reward; the
        driver pairs this with `recover_only` so their edge_N stays 0."""
        vals = np.where(mask, values, 0.0).astype(np.float32)
        return np.asarray(fx.encode(vals), np.int32), ~mask
