from repro.distributed.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_step, CheckpointManager,
)
from repro.distributed.fault import BSPFaultPolicy, HeartbeatMonitor

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager", "BSPFaultPolicy", "HeartbeatMonitor"]
