"""Fault-tolerant checkpointing with mesh-elastic restore.

Design (no orbax dependency — raw npz shards):
  * atomic: write to `step_<n>.tmp/`, fsync, rename to `step_<n>/` —
    a crash mid-write never corrupts the latest checkpoint;
  * manifest.json records the pytree structure, leaf shapes/dtypes and the
    mesh the state was saved under;
  * **elastic restore**: leaves are stored UNSHARDED (gathered to host),
    so a checkpoint saved on mesh A restores onto mesh B with any device
    count — restore() just applies the new shardings.  This is the
    checkpoint/restart + elastic-scaling story for node failures: lose a
    pod, restart on the remaining pod with the same numerics;
  * async: save() can run on a background thread (the train loop donates a
    host snapshot and keeps stepping) — CheckpointManager(async_save=True);
  * retention: keep_last N steps are retained, older ones pruned.

On a real multi-host pod, the host-gather becomes a per-host shard dump
(process_index-keyed files) — the single-process container exercises the
same code path with world size 1.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir, step: int, state, extra: dict | None = None):
    """Atomic unsharded checkpoint of a pytree `state`."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(state)
    host = [np.asarray(jax.device_get(l)) for l in leaves]
    np.savez(tmp / "leaves.npz", **{f"l{i}": a for i, a in enumerate(host)})
    manifest = {
        "step": step,
        "n_leaves": len(host),
        "treedef": str(treedef),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
        "time": time.time(),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    with open(tmp / "manifest.json") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, step: int, target, shardings=None):
    """Restore into the structure of `target` (pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedShardings for the CURRENT mesh — this is the elastic-restore
    path (saved mesh and restore mesh may differ arbitrarily)."""
    final = pathlib.Path(ckpt_dir) / f"step_{step}"
    data = np.load(final / "leaves.npz")
    manifest = json.loads((final / "manifest.json").read_text())
    leaves, treedef = _flatten(target)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, target {len(leaves)}")
    host = [data[f"l{i}"] for i in range(len(leaves))]
    for h, t in zip(host, leaves):
        assert tuple(h.shape) == tuple(t.shape), (h.shape, t.shape)
    if shardings is not None:
        shard_leaves, _ = _flatten(shardings)
        out = [jax.device_put(h.astype(t.dtype), s)
               for h, t, s in zip(host, leaves, shard_leaves)]
    else:
        out = [jax.numpy.asarray(h.astype(t.dtype)) for h, t in zip(host, leaves)]
    return jax.tree.unflatten(treedef, out), manifest


class CheckpointManager:
    """Retention + optional async save thread."""

    def __init__(self, ckpt_dir, keep_last: int = 3, async_save: bool = False):
        self.dir = pathlib.Path(ckpt_dir)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def save(self, step: int, state, extra=None):
        if self.async_save:
            # snapshot to host synchronously (cheap vs compile/step), write
            # asynchronously so the train loop overlaps I/O with compute.
            host = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), state)
            self.wait()
            self._thread = threading.Thread(
                target=self._save_now, args=(step, host, extra), daemon=True)
            self._thread.start()
        else:
            self._save_now(step, state, extra)

    def _save_now(self, step, state, extra):
        save_checkpoint(self.dir, step, state, extra)
        self._prune()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp"))
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def restore_latest(self, target, shardings=None):
        s = latest_step(self.dir)
        if s is None:
            return None, None, None
        state, manifest = restore_checkpoint(self.dir, s, target, shardings)
        return s, state, manifest
