"""Gradient compression for the inter-pod data-parallel axis.

At 2+ pods the gradient all-reduce crosses the (slow) inter-pod links;
compressing what crosses them is a standard distributed-optimization
trick.  Two composable schemes:

  * int8 quantization with per-tensor scale (8x over f32, 2x over bf16):
    value-preserving to ~0.4% rms on unit-scale grads;
  * top-k sparsification with error feedback (caller keeps the residual).

Both are pure functions so they can sit inside the jitted train step
(compress -> all-reduce -> decompress is expressed here as the
compress/decompress pair around the psum in the pod-sharded train step;
under plain pjit we apply them as a grad transform, which models the
numerics while GSPMD owns the collective).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(g):
    """[-max|g|, max|g|] -> int8 with per-tensor scale."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def int8_roundtrip(grads):
    """Grad transform used by make_train_step(compress_fn=...)."""
    def one(g):
        q, s = compress_int8(g)
        return decompress_int8(q, s, g.dtype)
    return jax.tree.map(one, grads)


def topk_sparsify(g, frac: float = 0.01):
    """Keep the top `frac` fraction of entries by magnitude; returns
    (sparse_g, residual) for error feedback."""
    gf = g.astype(jnp.float32)
    flat = gf.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    keep = jnp.abs(gf) >= thresh
    sparse = jnp.where(keep, gf, 0.0)
    return sparse.astype(g.dtype), (gf - sparse).astype(g.dtype)
