"""Optimizers (raw JAX): AdamW and Adafactor, with configurable state
dtypes.  Adafactor's factored second moment is what fits deepseek-v3's
671B-parameter optimizer state in HBM (DESIGN.md §5); AdamW is the default
for everything else.

API: make_optimizer(name, ...) -> (init_fn, update_fn)
  init_fn(params) -> opt_state
  update_fn(grads, opt_state, params, step) -> (updates, new_state)
(updates are ADDED to params by the caller.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(1.0, warmup)
        prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          state_dtype=jnp.float32):
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            u = -lr * ((m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
                       + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), m2.astype(state_dtype), v2.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    return init, update


def adafactor(lr_fn, decay=0.99, eps=1e-30, clip_threshold=1.0,
              min_dim_factored: int = 128):
    """Factored second-moment optimizer [Shazeer & Stern '18].  Tensors
    with >= 2 dims both >= min_dim_factored store row/col statistics only
    — O(n+m) instead of O(n*m) state."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def z(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)

        def upd(g, st, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if "vr" in st:
                vr = decay * st["vr"] + (1 - decay) * jnp.mean(g2, -1)
                vc = decay * st["vc"] + (1 - decay) * jnp.mean(g2, -2)
                denom = jnp.maximum(jnp.mean(vr, -1, keepdims=True), eps)
                vhat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = gf * jax.lax.rsqrt(vhat + eps)
                new = {"vr": vr, "vc": vc}
            else:
                v = decay * st["v"] + (1 - decay) * g2
                u = gf * jax.lax.rsqrt(v + eps)
                new = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * u).astype(p.dtype), new

        out = jax.tree.map(upd, grads, state["stats"], params,
                           is_leaf=lambda x: isinstance(x, dict) and (
                               "v" in x or "vr" in x))
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        stats = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"stats": stats}

    return init, update


def make_optimizer(name: str, lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000, **kw):
    lr_fn = cosine_schedule(lr, warmup, total)
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    raise ValueError(name)
