from repro.optim.optimizers import (
    adamw, adafactor, make_optimizer, clip_by_global_norm, cosine_schedule,
)
from repro.optim.compression import compress_int8, decompress_int8, topk_sparsify

__all__ = [
    "adamw", "adafactor", "make_optimizer", "clip_by_global_norm",
    "cosine_schedule", "compress_int8", "decompress_int8", "topk_sparsify",
]
