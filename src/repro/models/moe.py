"""Mixture-of-Experts layer (mixtral 8x22b, deepseek-v3).

Dispatch is sort-based (argsort by expert id -> capacity-bounded slots ->
gather / grouped einsum / scatter-combine), NOT one-hot-matmul dispatch:
the one-hot [tokens, E, C] tensor is O(T*E*C) and blows up at E = 256,
while sort dispatch keeps compiled FLOPs at ~active-expert FLOPs x
capacity_factor, which is what the roofline's MODEL_FLOPS/HLO_FLOPs ratio
should show.

Sharding: the expert-stacked weights carry the "experts" logical axis
(-> "model" mesh axis when divisible, e.g. deepseek 256/16; mixtral's 8
experts fall back to sharding the "mlp" dim).  Token dispatch across the
data axis is left to GSPMD in the baseline; the shard_map all-to-all
variant is a §Perf hillclimb.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import sharding as sh


def init_moe(cfg, key):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "wi": jax.random.normal(ks[1], (e, d, f), L.dt(cfg)) * s_in,
        "wg": jax.random.normal(ks[2], (e, d, f), L.dt(cfg)) * s_in,
        "wo": jax.random.normal(ks[3], (e, f, d), L.dt(cfg)) * s_out,
    }
    a = {
        "router": ("embed", "experts"),
        "wi": ("experts", "embed", "mlp"),
        "wg": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    if cfg.n_shared_experts:
        sh, sa = L.init_mlp(cfg, ks[4], d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
        p["shared"], a["shared"] = sh, sa
    return p, a


def moe_forward(cfg, p, x, capacity_factor: float = 1.25):
    """x: [B, S, d] -> (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    mesh, rules = sh.get_context()
    if mesh is not None and rules.moe_shard_map:
        y, aux = _moe_shard_map(cfg, p, x.reshape(T, d), capacity_factor,
                                mesh, rules)
        return y.reshape(B, S, d), aux
    return _moe_dense(cfg, p, x, capacity_factor)


def _moe_dense(cfg, p, x, capacity_factor):
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xf = x.reshape(T, d)

    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)                      # [T, E]
    gate, eidx = jax.lax.top_k(probs, K)                    # [T, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based capacity dispatch ----
    C = int(np.ceil(T * K / E * capacity_factor))
    C = max(8, -(-C // 8) * 8)                              # pad to 8
    fe = eidx.reshape(T * K)                                # flat expert ids
    ft = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)      # flat token ids
    fg = gate.reshape(T * K)
    order = jnp.argsort(fe, stable=True)
    se, st_, sg = fe[order], ft[order], fg[order]
    pos_all = jnp.arange(T * K, dtype=jnp.int32)
    newrun = jnp.concatenate([jnp.array([True]), se[1:] != se[:-1]])
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(newrun, pos_all, 0))
    slot_in_e = pos_all - run_start                         # rank inside expert
    keep = slot_in_e < C                                    # overflow dropped
    slot = jnp.where(keep, se * C + slot_in_e, E * C)       # OOB -> dropped

    xe = jnp.zeros((E * C, d), xf.dtype).at[slot].set(
        xf[st_], mode="drop").reshape(E, C, d)

    # §Perf: without an explicit constraint GSPMD tends to replicate the
    # dispatch tensor across the data axis, turning every expert matmul's
    # reduction into a full all-reduce of [E, C, d].  Pinning capacity to
    # the data axis (and experts to model when divisible) keeps the expert
    # FFN local and shrinks the combine collective by the DP degree.
    mesh, rules = sh.get_context()
    if mesh is not None and rules.moe_constraints:
        xe = sh.constrain(xe, ("experts", "batch", None))

    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = L.act_fn(cfg)(g) * h
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    if mesh is not None and rules.moe_constraints:
        ye = sh.constrain(ye, ("experts", "batch", None))
    ye = ye.reshape(E * C, d)

    contrib = ye[jnp.minimum(slot, E * C - 1)] * jnp.where(keep, sg, 0.0)[:, None].astype(ye.dtype)
    y = jnp.zeros((T, d), ye.dtype).at[st_].add(contrib)

    if cfg.n_shared_experts:
        y = y + L.mlp(cfg, p["shared"], xf)

    # load-balance aux loss (switch-style)
    me = jnp.mean(probs, 0)                                  # mean router prob
    ce = jnp.zeros(E, jnp.float32).at[fe].add(
        jnp.ones_like(fe, jnp.float32)) / (T * K)            # token fraction
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux


# --------------------------------------------------------------------------
# §Perf iteration 2 (mixtral train): shard_map expert path.
#
# Iteration 1 (with_sharding_constraint on the dispatch tensor) was REFUTED:
# GSPMD turned the token gather into a per-layer all-gather of the full
# token table (11.3 TB/device/step).  The fix is to make locality
# structural: shard_map over the data axes keeps each shard's dispatch,
# gather and scatter entirely local; the only collective left is the psum
# of the expert-FFN f-contraction partials (weights stay "mlp"-sharded on
# the model axis, e.g. mixtral's 8 experts that cannot shard 16 ways).
# --------------------------------------------------------------------------

def _gather_fsdp(w, spec, data_axes):
    """ZeRO-3 weight re-gather inside shard_map: any param dim the FSDP
    rules sharded over the data axes is all-gathered before use (this is
    the inherent FSDP collective; it shows up honestly in the roofline)."""
    for dim, s in enumerate(spec):
        names = (s,) if isinstance(s, str) else tuple(s or ())
        g = tuple(n for n in names if n in data_axes)
        if g:
            w = jax.lax.all_gather(w, g, axis=dim, tiled=True)
    return w


def _local_expert_ffn(cfg, p, xf, capacity_factor, model_axes):
    """Per-data-shard dispatch + expert FFN.  xf: [T_loc, d] (local)."""
    T, d = xf.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    C = int(np.ceil(T * K / E * capacity_factor))
    C = max(8, -(-C // 8) * 8)
    fe = eidx.reshape(T * K)
    ft = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    fg = gate.reshape(T * K)
    order = jnp.argsort(fe, stable=True)
    se, st_, sg = fe[order], ft[order], fg[order]
    pos_all = jnp.arange(T * K, dtype=jnp.int32)
    newrun = jnp.concatenate([jnp.array([True]), se[1:] != se[:-1]])
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(newrun, pos_all, 0))
    slot_in_e = pos_all - run_start
    keep = slot_in_e < C
    slot = jnp.where(keep, se * C + slot_in_e, E * C)

    xe = jnp.zeros((E * C, d), xf.dtype).at[slot].set(
        xf[st_], mode="drop").reshape(E, C, d)
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = L.act_fn(cfg)(g) * h
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * C, d)
    contrib = ye[jnp.minimum(slot, E * C - 1)] \
        * jnp.where(keep, sg, 0.0)[:, None].astype(ye.dtype)
    y = jnp.zeros((T, d), ye.dtype).at[st_].add(contrib)
    if cfg.n_shared_experts:
        y = y + L.mlp(cfg, p["shared"], xf)
    # f-contraction partials across the model axis
    y = jax.lax.psum(y, model_axes)

    me = jnp.mean(probs, 0)
    ce = jnp.zeros(E, jnp.float32).at[fe].add(
        jnp.ones_like(fe, jnp.float32)) / (T * K)
    aux = E * jnp.sum(me * ce)
    return y, aux


def _moe_shard_map(cfg, p, xf, capacity_factor, mesh, rules):
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # older jax
        from jax.experimental.shard_map import shard_map

    data_axes = tuple(a for a in rules.batch if a in mesh.axis_names)
    model_axes = tuple(a for a in rules.model if a in mesh.axis_names)
    # param specs must match their installed shardings
    leaf = lambda t: isinstance(t, tuple) and all(
        isinstance(e, (str, type(None))) for e in t)
    from repro.models.moe import init_moe as _  # noqa
    axes = _moe_axes(cfg)
    p_specs = jax.tree.map(
        lambda ax, w: sh.spec_for_param(mesh, rules, ax, w.shape),
        axes, p, is_leaf=leaf)

    def fn(pp, xx):
        # explicit two-level walk: PartitionSpec is a tuple subclass, so a
        # generic tree.map would flatten it
        pp = {
            k: ({k2: _gather_fsdp(v2, p_specs[k][k2], data_axes)
                 for k2, v2 in v.items()} if isinstance(v, dict)
                else _gather_fsdp(v, p_specs[k], data_axes))
            for k, v in pp.items()
        }
        return _local_expert_ffn(cfg, pp, xx, capacity_factor, model_axes)

    y, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(p_specs, P(data_axes if data_axes else None, None)),
        out_specs=(P(data_axes if data_axes else None, None), P()),
        check_vma=False,
    )(p, xf)
    return y, aux


def _moe_axes(cfg):
    a = {
        "router": ("embed", "experts"),
        "wi": ("experts", "embed", "mlp"),
        "wg": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    if cfg.n_shared_experts:
        a["shared"] = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
        if cfg.gated_mlp:
            a["shared"]["wg"] = ("embed", "mlp")
    return a
