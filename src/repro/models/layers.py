"""Shared neural-net layers (raw JAX, no framework deps).

Params are plain dict pytrees.  Every ``init_*`` returns ``(params, axes)``
where ``axes`` mirrors the params pytree with a tuple of *logical* axis
names per array dim — consumed by models.sharding to build NamedShardings
(with divisibility fallbacks) for the production mesh.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ----------------------------------------------------------------- norms

def init_norm(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        p = {"scale": jnp.ones((d,), jnp.float32),
             "bias": jnp.zeros((d,), jnp.float32)}
        a = {"scale": ("embed",), "bias": ("embed",)}
    else:
        p = {"scale": jnp.ones((d,), jnp.float32)}
        a = {"scale": ("embed",)}
    return p, a


def norm(cfg, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return y.astype(x.dtype)


# ------------------------------------------------------------ activations

def act_fn(cfg):
    return jax.nn.silu if cfg.act == "silu" else jax.nn.gelu


# ------------------------------------------------------------------- mlp

def init_mlp(cfg, key, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {"wi": jax.random.normal(k1, (d, f), dt(cfg)) * s_in,
         "wo": jax.random.normal(k2, (f, d), dt(cfg)) * s_out}
    a = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if cfg.gated_mlp:
        p["wg"] = jax.random.normal(k3, (d, f), dt(cfg)) * s_in
        a["wg"] = ("embed", "mlp")
    return p, a


def mlp(cfg, p, x):
    h = x @ p["wi"]
    if cfg.gated_mlp:
        h = act_fn(cfg)(x @ p["wg"]) * h
    else:
        h = act_fn(cfg)(h)
    return h @ p["wo"]


# ------------------------------------------------------------- embedding

def init_embed(cfg, key):
    v, d = cfg.padded_vocab, cfg.d_model
    p = {"tok": jax.random.normal(key, (v, d), jnp.float32) * 0.02}
    a = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["out"] = jax.random.normal(
            jax.random.fold_in(key, 1), (d, v), jnp.float32) * 0.02
        a["out"] = ("embed", "vocab")
    return p, a


def embed(cfg, p, tokens):
    x = jnp.take(p["tok"].astype(dt(cfg)), tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt(cfg))
    return x


def unembed(cfg, p, x):
    w = p["out"] if "out" in p else p["tok"].T
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


# ------------------------------------------------------------------ rope

def rope(x, positions, theta: float):
    """x: [..., S, H, dh]; positions: [..., S] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-np.arange(0, half, dtype=np.float32) / half)
    # [..., S, 1, half]: broadcast over the head dim
    ang = positions[..., :, None, None].astype(jnp.float32) * freq
    x1, x2 = x[..., :half], x[..., half:]
    c, s = jnp.cos(ang), jnp.sin(ang)
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- conv1d

def init_conv1d(key, width, channels):
    p = {"w": jax.random.normal(key, (width, channels), jnp.float32) * 0.1,
         "b": jnp.zeros((channels,), jnp.float32)}
    a = {"w": (None, "mlp"), "b": ("mlp",)}
    return p, a


def causal_conv1d(p, x, state=None):
    """Depthwise causal conv.  x: [B, S, C].
    state: [B, width-1, C] trailing context (decode) or None (train).
    Returns (y, new_state)."""
    w = p["w"].astype(x.dtype)  # [W, C]
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+W-1, C]
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(width))
    y = y + p["b"].astype(x.dtype)
    new_state = xp[:, -(width - 1):, :] if width > 1 else pad
    return y, new_state
