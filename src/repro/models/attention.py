"""Attention: GQA (full / sliding-window / prefix-causal), MLA (deepseek),
cross-attention (whisper) — with KV caches for serving.

Compute paths:
  * "blockwise" (default): flash-style online-softmax over KV chunks in
    pure jnp (lax.scan) — O(S) memory, used for training/prefill and in
    the multi-pod dry-run.  Sliding-window layers iterate only the KV
    chunks inside the window, so windowed archs get their FLOPs savings
    in the compiled HLO (this matters for the roofline, not just speed).
  * "naive": materialized scores, small shapes/tests only.
  * the Pallas flash kernel (repro.kernels.flash_attention) is the
    TPU-optimized variant of the same math, validated against this module.

Decode path attends a single query over the cache buffer with a validity
mask; windowed layers use a ring buffer of size `window` so a 500k-token
stream costs O(window) memory (mixtral / gemma3-local / rg local attn).

Caches store K *after* RoPE (absolute positions), the standard choice that
makes ring buffers safe.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

NEG = -2.0e38
BLOCK_Q = 512
BLOCK_K = 512


# ------------------------------------------------------------------ init

def init_attn(cfg, key, spec):
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(H * dh)
    if cfg.attn_impl == "mla":
        qh = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = {
            "wq_a": jax.random.normal(ks[0], (d, cfg.q_lora_rank), L.dt(cfg)) * s,
            "wq_b": jax.random.normal(ks[1], (cfg.q_lora_rank, H, qh), L.dt(cfg))
            * (1.0 / math.sqrt(cfg.q_lora_rank)),
            "wkv_a": jax.random.normal(
                ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), L.dt(cfg)) * s,
            "wkv_b": jax.random.normal(
                ks[3], (cfg.kv_lora_rank, H, cfg.qk_nope_dim + cfg.v_head_dim),
                L.dt(cfg)) * (1.0 / math.sqrt(cfg.kv_lora_rank)),
            "wo": jax.random.normal(ks[4], (H, cfg.v_head_dim, d), L.dt(cfg)) * so,
        }
        a = {
            "wq_a": ("embed", "lora"),
            "wq_b": ("lora", "heads", "head_dim"),
            "wkv_a": ("embed", "lora"),
            "wkv_b": ("lora", "heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed"),
        }
    else:
        p = {
            "wq": jax.random.normal(ks[0], (d, H, dh), L.dt(cfg)) * s,
            "wk": jax.random.normal(ks[1], (d, Hkv, dh), L.dt(cfg)) * s,
            "wv": jax.random.normal(ks[2], (d, Hkv, dh), L.dt(cfg)) * s,
            "wo": jax.random.normal(ks[3], (H, dh, d), L.dt(cfg)) * so,
        }
        a = {
            "wq": ("embed", "heads", "head_dim"),
            "wk": ("embed", "kv_heads", "head_dim"),
            "wv": ("embed", "kv_heads", "head_dim"),
            "wo": ("heads", "head_dim", "embed"),
        }
    if spec.cross_attn:
        p["xq"] = jax.random.normal(ks[5], (d, H, dh), L.dt(cfg)) * s
        p["xk"] = jax.random.normal(ks[6], (d, Hkv, dh), L.dt(cfg)) * s
        p["xv"] = jax.random.normal(ks[7], (d, Hkv, dh), L.dt(cfg)) * s
        p["xo"] = jax.random.normal(
            jax.random.fold_in(key, 99), (H, dh, d), L.dt(cfg)) * so
        nrm, na = L.init_norm(cfg)
        p["xnorm"] = nrm
        a.update({"xq": ("embed", "heads", "head_dim"),
                  "xk": ("embed", "kv_heads", "head_dim"),
                  "xv": ("embed", "kv_heads", "head_dim"),
                  "xo": ("heads", "head_dim", "embed"),
                  "xnorm": na})
    return p, a


# ----------------------------------------------------- blockwise attention

def _gqa_scores(q, k):
    """q: [B,Sq,H,dh], k: [B,Sk,Hkv,dh] -> [B,H,Sq,Sk] without repeating k."""
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    return s.reshape(B, Hkv * g, Sq, k.shape[1])


def _gqa_out(p_attn, v):
    """p: [B,H,Sq,Sk], v: [B,Sk,Hkv,dh] -> [B,Sq,H,dh]."""
    B, H, Sq, Sk = p_attn.shape
    Hkv = v.shape[2]
    g = H // Hkv
    pg = p_attn.reshape(B, Hkv, g, Sq, Sk)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v.astype(p_attn.dtype),
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, v.shape[3])


def naive_attention(q, k, v, *, causal, window=None, prefix=0,
                    q_offset=0, kv_valid=None, scale=None):
    """Reference attention with materialized scores (tests / tiny shapes).

    prefix: first `prefix` query/key positions attend bidirectionally
    (paligemma image prefix).  q_offset: absolute position of q[0] relative
    to k[0] (decode).  kv_valid: [B, Sk] bool mask of valid cache slots.
    """
    scale = scale or (1.0 / math.sqrt(q.shape[-1]))
    s = _gqa_scores(q * scale, k)
    Sq, Sk = s.shape[-2], s.shape[-1]
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    m = jnp.ones((Sq, Sk), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
        if prefix:
            m |= (kpos[None, :] < prefix) & jnp.ones((Sq, 1), bool)
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(m, s, NEG)
    if kv_valid is not None:
        s = jnp.where(kv_valid[:, None, None, :], s, NEG)
    p_attn = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p_attn.astype(v.dtype), v)


def blockwise_attention(q, k, v, *, causal=True, window=None, prefix=0,
                        scale=None):
    """Flash-style attention in jnp: scan over KV blocks with an online
    softmax.  Windowed layers visit only in-window KV blocks."""
    scale = scale or (1.0 / math.sqrt(q.shape[-1]))
    B, S, H, dh = q.shape
    Sk = k.shape[1]
    dhv = v.shape[-1]
    bq, bk = min(BLOCK_Q, S), min(BLOCK_K, Sk)
    nq, nk = -(-S // bq), -(-Sk // bk)
    Sp, Skp = nq * bq, nk * bk
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, bq, H, dh).transpose(1, 0, 2, 3, 4)   # [nq,B,bq,H,dh]
    kb = kp.reshape(B, nk, bk, k.shape[2], dh).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, bk, v.shape[2], dhv).transpose(1, 0, 2, 3, 4)

    # how many kv blocks behind the diagonal a query block must visit
    w_blocks = nk if window is None else min(nk, window // bk + 2)

    def q_block(qi, qblk):
        qpos = qi * bq + jnp.arange(bq)

        def kv_step(carry, rel):
            m_run, l_run, acc = carry
            if causal:
                kj_raw = qi * bq // bk - rel
                kj = jnp.clip(kj_raw, 0, nk - 1)
                step_ok = kj_raw >= 0        # don't re-visit block 0
            else:
                kj, step_ok = rel, jnp.asarray(True)
            kblk, vblk = kb[kj], vb[kj]
            kpos = kj * bk + jnp.arange(bk)
            s = _gqa_scores((qblk * scale)[:, :, :, :], kblk)   # [B,H,bq,bk]
            msk = jnp.ones((bq, bk), bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
                if prefix:
                    msk |= (kpos[None, :] < prefix) & jnp.ones((bq, 1), bool)
            if window is not None:
                msk &= kpos[None, :] > qpos[:, None] - window
            msk &= (kpos < Sk)[None, :]
            msk &= step_ok
            s = jnp.where(msk, s, NEG)
            m_new = jnp.maximum(m_run, jnp.max(s, -1))
            alpha = jnp.exp(m_run - m_new)
            p_b = jnp.exp(s - m_new[..., None])
            l_run = l_run * alpha + jnp.sum(p_b, -1)
            acc = acc * alpha[..., None] + _block_out(p_b, vblk)
            return (m_new, l_run, acc), None

        m0 = jnp.full((B, H, bq), NEG, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        a0 = jnp.zeros((B, H, bq, dhv), jnp.float32)
        steps = w_blocks if causal else nk
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc := a0), jnp.arange(steps))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3)                         # [B,bq,H,dhv]

    outs = jax.lax.map(lambda i: q_block(i, qb[i]), jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, dhv)[:, :S]
    return out.astype(v.dtype)


def _block_out(p_attn, vblk):
    """[B,H,bq,bk] x [B,bk,Hkv,dhv] -> [B,H,bq,dhv] (GQA-aware)."""
    B, H, bq, bk = p_attn.shape
    Hkv = vblk.shape[2]
    g = H // Hkv
    pg = p_attn.reshape(B, Hkv, g, bq, bk)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", pg, vblk.astype(jnp.float32))
    return o.reshape(B, H, bq, vblk.shape[3])


# --------------------------------------------------------------- forward

def attn_forward(cfg, spec, p, x, positions, cache=None, impl="blockwise"):
    """Self-attention.  x: [B,S,d].  cache: None (train/prefill without
    cache), or dict(k,v,pos) for decode / prefill-with-cache.
    Returns (out [B,S,d], new_cache)."""
    if cfg.attn_impl == "mla":
        return _mla_forward(cfg, spec, p, x, positions, cache, impl)
    B, S, d = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        new_cache = _cache_update(cfg, spec, cache, k, v, positions)
        if S == 1:  # decode
            out = _decode_attend(cfg, spec, q, new_cache, positions)
            return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache
        # prefill-with-cache: attend over the raw (unwrapped) K/V; the ring
        # buffer is only for subsequent decode steps.

    if impl == "naive":
        out = naive_attention(q, k, v, causal=True, window=spec.window,
                              prefix=cfg.vlm_patches)
    else:
        out = blockwise_attention(q, k, v, causal=True, window=spec.window,
                                  prefix=cfg.vlm_patches)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def cross_attn_forward(cfg, p, x, enc_kv):
    """Whisper decoder cross-attention; enc_kv = (k, v) precomputed at
    prefill from encoder output."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["xq"])
    out = naive_attention(q, k, v, causal=False) if k.shape[1] <= 2048 else \
        blockwise_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["xo"])


def encode_cross_kv(cfg, p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["xk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["xv"])
    return k, v


# ------------------------------------------------------------------ cache

def init_cache(cfg, spec, batch, max_seq):
    """Preallocated decode cache for one attention layer."""
    if cfg.attn_impl == "mla":
        width = cfg.kv_lora_rank + cfg.qk_rope_dim
        buf = max_seq if spec.window is None else min(spec.window, max_seq)
        return {"c": jnp.zeros((batch, buf, width), L.dt(cfg)),
                "pos": jnp.zeros((), jnp.int32)}
    buf = max_seq if spec.window is None else min(spec.window, max_seq)
    shape = (batch, buf, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, L.dt(cfg)),
            "v": jnp.zeros(shape, L.dt(cfg)),
            "pos": jnp.zeros((), jnp.int32)}


def _cache_update(cfg, spec, cache, k, v, positions):
    """Write new entries at their (ring-buffered if windowed) slots.
    When prefilling more tokens than the buffer holds, keep the last `buf`
    (slot-duplicate scatters have unspecified winner semantics).
    positions: [S] shared, or [B, S] per-row (ragged continuous batching)."""
    buf = cache["k"].shape[1]
    if k.shape[1] > buf:
        k, v = k[:, -buf:], v[:, -buf:]
        positions = positions[..., -buf:]
    slots = positions % buf
    if slots.ndim == 2:  # per-row scatter
        b_idx = jnp.arange(k.shape[0])[:, None]
        ck = cache["k"].at[b_idx, slots].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[b_idx, slots].set(v.astype(cache["v"].dtype))
    else:
        ck = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
    return {"k": ck, "v": cv, "pos": jnp.max(positions) + 1}


def _decode_attend(cfg, spec, q, cache, positions):
    """Single-token attention over the cache buffer with validity mask.
    positions: [1] shared, or [B, 1] per-row (ragged batching)."""
    B = q.shape[0]
    buf = cache["k"].shape[1]
    cur = positions[..., -1]                          # [] or [B]
    slot_pos = _slot_positions(buf, cur)              # [buf] or [B, buf]
    curb = cur[..., None]
    # slot_pos < 0 marks never-written ring slots (first lap)
    valid = (slot_pos <= curb) & (slot_pos >= 0)
    if spec.window is not None:
        valid &= slot_pos > curb - spec.window
    valid = jnp.broadcast_to(valid, (B, buf)) if valid.ndim == 2 \
        else jnp.broadcast_to(valid[None], (B, buf))
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = _gqa_scores(q * scale, cache["k"])            # [B,H,1,buf]
    s = jnp.where(valid[:, None, None, :], s, NEG)
    p_attn = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p_attn.astype(cache["v"].dtype), cache["v"])


def _slot_positions(buf, cur):
    """Absolute position stored in each ring slot, given next-pos = cur.
    cur: [] or [B] -> [buf] or [B, buf]."""
    idx = jnp.arange(buf)
    c = cur[..., None] if getattr(cur, "ndim", 0) else cur
    wrap = (c // buf) * buf + idx
    return jnp.where(idx <= c % buf, wrap, wrap - buf)


# -------------------------------------------------------------------- MLA

def _mla_forward(cfg, spec, p, x, positions, cache, impl):
    """DeepSeek-V3 multi-head latent attention.

    The KV cache stores only the compressed latent c_kv (kv_lora_rank) and
    the shared rope key (qk_rope_dim) per token — the memory win that makes
    long-context MLA serving viable.  For compute we decompress per block
    (naive/blockwise on decompressed K/V keeps one attention code path; the
    absorbed-matmul trick is a TPU kernel optimization left to §Perf).
    """
    B, S, d = x.shape
    H = cfg.n_heads
    rq, rkv, rr = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.qk_rope_dim
    nope, dv = cfg.qk_nope_dim, cfg.v_head_dim

    q = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    q = jnp.einsum("bsr,rhk->bshk", q, p["wq_b"])     # [B,S,H,nope+rr]
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], -1)

    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])    # [B,S,rkv+rr]
    c, k_rope = ckv[..., :rkv], ckv[..., rkv:]
    k_rope = L.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    ckv = jnp.concatenate([c, k_rope], -1)

    new_cache = None
    if cache is not None:
        buf = cache["c"].shape[1]
        ckv_w, pos_w = ckv, positions
        if S > buf:
            ckv_w, pos_w = ckv[:, -buf:], positions[..., -buf:]
        slots = pos_w % buf
        if slots.ndim == 2:   # per-row (ragged batching)
            b_idx = jnp.arange(B)[:, None]
            cc = cache["c"].at[b_idx, slots].set(
                ckv_w.astype(cache["c"].dtype))
        else:
            cc = cache["c"].at[:, slots].set(ckv_w.astype(cache["c"].dtype))
        new_cache = {"c": cc, "pos": jnp.max(positions) + 1}
        ckv_all = cc if S == 1 else ckv   # decode reads buffer; prefill raw
    else:
        ckv_all = ckv

    c_all, kr_all = ckv_all[..., :rkv], ckv_all[..., rkv:]

    if cache is not None and S == 1:
        cur = positions[..., -1]
        buf = ckv_all.shape[1]
        slot_pos = _slot_positions(buf, cur)
        ok = (slot_pos <= cur[..., None]) & (slot_pos >= 0)
        ok = jnp.broadcast_to(ok if ok.ndim == 2 else ok[None], (B, buf))
        if getattr(cfg, "mla_absorb", False):
            # Beyond-paper serving optimization (the deepseek "absorbed"
            # trick): attend in the compressed latent space instead of
            # decompressing the whole cache per token.
            #   q_abs[h] = q_nope[h] @ W_kv^nope[h]^T   -> [B,H,rkv]
            #   score    = q_abs . c  +  q_rope . k_rope
            #   out[h]   = (attn @ c) @ W_kv^v[h]
            # Per-step work drops from O(S*H*(nope+dv)*rkv) decompression
            # to O(S*H*(rkv+rr)) score math.
            w_nope = p["wkv_b"][..., :nope]              # [rkv, H, nope]
            w_v = p["wkv_b"][..., nope:]                 # [rkv, H, dv]
            q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, w_nope)
            scale = 1.0 / math.sqrt(nope + rr)
            s_lat = jnp.einsum("bshr,btr->bhst", q_abs, c_all,
                               preferred_element_type=jnp.float32)
            s_rope = jnp.einsum("bshk,btk->bhst", q_rope, kr_all,
                                preferred_element_type=jnp.float32)
            s = (s_lat + s_rope) * scale
            s = jnp.where(ok[:, None, None, :], s, NEG)
            a_w = jax.nn.softmax(s, axis=-1)
            ctx = jnp.einsum("bhst,btr->bshr", a_w.astype(c_all.dtype), c_all)
            out = jnp.einsum("bshr,rhk->bshk", ctx, w_v)
            return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache

    # decompress latents to per-head K/V (train/prefill, or naive decode)
    kv = jnp.einsum("bsr,rhk->bshk", c_all, p["wkv_b"])
    k = jnp.concatenate(
        [kv[..., :nope],
         jnp.broadcast_to(kr_all[:, :, None, :],
                          kv.shape[:3] + (rr,))], -1)  # [B,Sk,H,nope+rr]
    v = kv[..., nope:]

    if cache is not None and S == 1:
        cur = positions[..., -1]
        buf = ckv_all.shape[1]
        slot_pos = _slot_positions(buf, cur)
        ok = (slot_pos <= cur[..., None]) & (slot_pos >= 0)
        ok = jnp.broadcast_to(ok if ok.ndim == 2 else ok[None], (B, buf))
        out = naive_attention(q, k, v, causal=False, kv_valid=ok,
                              scale=1.0 / math.sqrt(nope + rr))
    elif impl == "naive" or S <= 2048:
        out = naive_attention(q, k, v, causal=True,
                              scale=1.0 / math.sqrt(nope + rr))
    else:
        out = blockwise_attention(q, k, v, causal=True,
                                  scale=1.0 / math.sqrt(nope + rr))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache
