"""Model assembly: generic decoder LM covering all 10 assigned archs.

Layers are stacked per (pattern, repeats) group and executed with
lax.scan, so compiled HLO size is O(|pattern|), not O(n_layers) — critical
for dry-run compile times at 48-61 layers on 512 host devices.  Each scan
body is rematerialized (jax.checkpoint) for training-memory sanity.

Supports: dense GQA (llama/granite/starcoder2), local:global patterns
(gemma3), VLM prefix (paligemma, stubbed patch embeddings), enc-dec with
cross-attention (whisper, stubbed frame embeddings), RG-LRU hybrid
(recurrentgemma), SSD (mamba2), MoE (mixtral, deepseek incl. MLA + shared
expert + optional MTP head).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssd as S
from repro.models.config import LayerSpec, ModelConfig
from repro.models.sharding import constrain


# ------------------------------------------------------------------ init

def _init_layer(cfg, spec: LayerSpec, key):
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["norm_in"], a["norm_in"] = L.init_norm(cfg)
    if spec.kind == "attn":
        p["mix"], a["mix"] = A.init_attn(cfg, ks[0], spec)
    elif spec.kind == "ssd":
        p["mix"], a["mix"] = S.init_ssd(cfg, ks[0])
    elif spec.kind == "rglru":
        p["mix"], a["mix"] = R.init_rglru(cfg, ks[0])
    else:
        raise ValueError(spec.kind)
    if spec.mlp == "dense":
        p["norm_mlp"], a["norm_mlp"] = L.init_norm(cfg)
        p["mlp"], a["mlp"] = L.init_mlp(cfg, ks[1])
    elif spec.mlp == "moe":
        p["norm_mlp"], a["norm_mlp"] = L.init_norm(cfg)
        p["moe"], a["moe"] = M.init_moe(cfg, ks[1])
    return p, a


def _stack_axes(a):
    return jax.tree.map(
        lambda t: ("stack",) + t,
        a,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


# Side channel for the logical-axes pytree: axes are deterministic Python
# constants assembled while init_params traces, but strings cannot be traced
# outputs — so init_params returns params only and stashes axes here.
_LAST_AXES: list = [None]


def init_params(cfg: ModelConfig, key):
    """Returns the params pytree (axes via param_axes()).  Run under
    jax.eval_shape for allocation-free shapes in the dry-run."""
    p, a = {}, {}
    kk = jax.random.split(key, 8)
    p["embed"], a["embed"] = L.init_embed(cfg, kk[0])
    for gi, (pattern, repeats) in enumerate(cfg.groups):
        keys = jax.random.split(jax.random.fold_in(kk[1], gi), repeats)
        gp, ga = [], []
        for i, spec in enumerate(pattern):
            one = lambda k, i=i, spec=spec: _init_layer(
                cfg, spec, jax.random.fold_in(k, i))[0]
            gp.append(jax.vmap(one)(keys))
            _, ax = _init_layer(cfg, spec, keys[0])
            ga.append(_stack_axes(ax))
        p[f"g{gi}"], a[f"g{gi}"] = gp, ga
    p["final_norm"], a["final_norm"] = L.init_norm(cfg)

    if cfg.encoder is not None:
        spec = LayerSpec(kind="attn", window=None, mlp="dense")
        keys = jax.random.split(kk[2], cfg.encoder.n_layers)
        one = lambda k: _init_layer(cfg, spec, k)[0]
        p["encoder"] = {"layers": jax.vmap(one)(keys)}
        _, ax = _init_layer(cfg, spec, keys[0])
        a["encoder"] = {"layers": _stack_axes(ax)}
        p["encoder"]["norm"], a["encoder"]["norm"] = L.init_norm(cfg)

    if cfg.mtp:
        spec = LayerSpec(kind="attn", window=None, mlp="dense")
        p["mtp"] = {"proj": jax.random.normal(
            kk[3], (2 * cfg.d_model, cfg.d_model), L.dt(cfg)) * 0.01}
        a["mtp"] = {"proj": ("embed", "embed")}
        p["mtp"]["block"], a["mtp"]["block"] = _init_layer(cfg, spec, kk[4])
        p["mtp"]["norm"], a["mtp"]["norm"] = L.init_norm(cfg)
    _LAST_AXES[0] = a
    return p


def param_axes(cfg: ModelConfig):
    """Logical-axes pytree matching init_params' structure (no allocation)."""
    jax.eval_shape(functools.partial(init_params, cfg), jax.random.PRNGKey(0))
    return _LAST_AXES[0]


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# ------------------------------------------------------------------ block

def _block(cfg, spec: LayerSpec, p, x, positions, cache, enc_out, impl):
    h = L.norm(cfg, p["norm_in"], x)
    if spec.kind == "attn":
        mix, new_cache = A.attn_forward(cfg, spec, p["mix"], h, positions,
                                        cache, impl)
        if spec.cross_attn:
            if enc_out is not None:
                # train / prefill: compute cross-KV from the encoder output
                # and (when serving) store it in the cache for decode.
                enc_kv = A.encode_cross_kv(cfg, p["mix"], enc_out)
            else:
                enc_kv = (cache["xk"], cache["xv"])
            if new_cache is not None:
                new_cache = dict(new_cache, xk=enc_kv[0], xv=enc_kv[1])
            xh = L.norm(cfg, p["mix"]["xnorm"], x)
            mix = mix + A.cross_attn_forward(cfg, p["mix"], xh, enc_kv)
    elif spec.kind == "ssd":
        mix, new_cache = S.ssd_forward(cfg, p["mix"], h, cache)
    else:
        mix, new_cache = R.rglru_forward(cfg, p["mix"], h, cache)
    # residual stream stays in cfg.dtype (attention/moe internals upcast to
    # f32; without this cast the layer-scan carry would change dtype)
    x = x + mix.astype(x.dtype)
    x = constrain(x, ("batch", "seq", "embed"))
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp == "dense":
        x = x + L.mlp(cfg, p["mlp"], L.norm(cfg, p["norm_mlp"], x)).astype(x.dtype)
    elif spec.mlp == "moe":
        y, aux = M.moe_forward(cfg, p["moe"], L.norm(cfg, p["norm_mlp"], x))
        x = x + y.astype(x.dtype)
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


def _run_group(cfg, pattern, stacked, x, positions, caches, enc_out, impl,
               remat=True):
    """Scan one (pattern, repeats) group.  caches: list (per position) of
    stacked cache pytrees or None."""
    has_cache = caches is not None

    def body(carry, xs):
        x, aux = carry
        ps = xs[0] if has_cache else xs
        cs = xs[1] if has_cache else [None] * len(pattern)
        new_cs = []
        for i, spec in enumerate(pattern):
            x, nc, a_i = _block(cfg, spec, ps[i], x, positions, cs[i],
                                enc_out, impl)
            aux = aux + a_i
            new_cs.append(nc)
        return (x, aux), (new_cs if has_cache else None)

    if remat:
        body = jax.checkpoint(body)
    xs = (stacked, caches) if has_cache else stacked
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, new_caches


# ---------------------------------------------------------------- forward

def encode(cfg, params, frames, impl="blockwise"):
    """Whisper encoder over stubbed frame embeddings [B, T, d]."""
    spec = LayerSpec(kind="attn", window=None, mlp="dense")
    B, T, _ = frames.shape
    positions = jnp.arange(T, dtype=jnp.int32)

    def body(x, ps):
        h = L.norm(cfg, ps["norm_in"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, ps["mix"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, ps["mix"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, ps["mix"]["wv"])
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        o = (A.naive_attention(q, k, v, causal=False) if T <= 2048
             else A.blockwise_attention(q, k, v, causal=False))
        x = x + jnp.einsum("bshk,hkd->bsd", o, ps["mix"]["wo"]).astype(x.dtype)
        x = x + L.mlp(cfg, ps["mlp"],
                      L.norm(cfg, ps["norm_mlp"], x)).astype(x.dtype)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body), frames.astype(L.dt(cfg)),
                        params["encoder"]["layers"])
    return L.norm(cfg, params["encoder"]["norm"], x)


def forward(
    cfg: ModelConfig,
    params,
    tokens,                   # [B, S] i32
    positions=None,           # [S] i32 (defaults arange; decode: [1])
    caches=None,              # from init_caches, or None
    patches=None,             # [B, P, d] paligemma stub embeddings
    frames=None,              # [B, T, d] whisper stub frame embeddings
    enc_out=None,             # precomputed encoder output (decode path)
    impl="blockwise",
    return_hidden=False,      # also return pre-unembed hidden (MTP loss)
):
    """Returns (logits [B, S(+P), V], new_caches, aux_loss[, hidden])."""
    B, Stok = tokens.shape
    x = L.embed(cfg, params["embed"], tokens)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    S_ = x.shape[1]
    if positions is None:
        positions = jnp.arange(S_, dtype=jnp.int32)
    x = constrain(x, ("batch", "seq", "embed"))

    if cfg.encoder is not None and enc_out is None and frames is not None:
        enc_out = encode(cfg, params, frames, impl)

    aux = jnp.zeros((), jnp.float32)
    new_caches = {} if caches is not None else None
    for gi, (pattern, repeats) in enumerate(cfg.groups):
        c = caches.get(f"g{gi}") if caches is not None else None
        x, a_g, nc = _run_group(cfg, pattern, params[f"g{gi}"], x, positions,
                                c, enc_out, impl)
        aux = aux + a_g
        if caches is not None:
            new_caches[f"g{gi}"] = nc
    x = L.norm(cfg, params["final_norm"], x)
    logits = L.unembed(cfg, params["embed"], x)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    if return_hidden:
        return logits, new_caches, aux, x
    return logits, new_caches, aux


# ------------------------------------------------------------------ cache

def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    """Stacked cache pytree aligned with the grouped layer stacks."""
    caches = {}
    for gi, (pattern, repeats) in enumerate(cfg.groups):
        per_pos = []
        for spec in pattern:
            one = _make_cache_init(cfg, spec, batch, max_seq)
            stacked = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (repeats,) + l.shape).copy()
                if repeats > 1 else l[None], one)
            per_pos.append(stacked)
        caches[f"g{gi}"] = per_pos
    return caches


def _make_cache_init(cfg, spec: LayerSpec, batch, max_seq):
    if spec.kind == "attn":
        c = A.init_cache(cfg, spec, batch, max_seq)
        if spec.cross_attn and cfg.encoder is not None:
            shape = (batch, cfg.encoder.n_frames, cfg.n_kv_heads, cfg.head_dim)
            c["xk"] = jnp.zeros(shape, L.dt(cfg))
            c["xv"] = jnp.zeros(shape, L.dt(cfg))
        return c
    if spec.kind == "ssd":
        return S.init_ssd_cache(cfg, batch)
    return R.init_rglru_cache(cfg, batch)


# --------------------------------------------------------------- MTP head

def mtp_logits(cfg, params, h, next_embeds, positions, impl="naive"):
    """DeepSeek-V3 multi-token prediction: predict t+2 from trunk state at
    t combined with the embedding of token t+1."""
    z = jnp.concatenate([h, next_embeds.astype(h.dtype)], axis=-1)
    z = z @ params["mtp"]["proj"]
    spec = LayerSpec(kind="attn", window=None, mlp="dense")
    z, _, _ = _block(cfg, spec, params["mtp"]["block"], z, positions, None,
                     None, impl)
    z = L.norm(cfg, params["mtp"]["norm"], z)
    return L.unembed(cfg, params["embed"], z)
