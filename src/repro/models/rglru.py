"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> (gate branch: linear+GeLU) * (recurrent branch: linear ->
causal conv -> RG-LRU) -> out projection.

RG-LRU recurrence (fp32):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses an associative scan over (a, b) pairs (O(log S) depth);
decode is the O(1) per-token step — with the local-attention layers'
bounded windows this is what qualifies the arch for long_500k.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

_C = 8.0


def init_rglru(cfg, key):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    p = {
        "in_x": jax.random.normal(ks[0], (d, w), L.dt(cfg)) * s,
        "in_gate": jax.random.normal(ks[1], (d, w), L.dt(cfg)) * s,
        "conv": {"w": jax.random.normal(ks[2], (cfg.conv_width, w),
                                        jnp.float32) * 0.1,
                 "b": jnp.zeros((w,), jnp.float32)},
        "wa": jax.random.normal(ks[3], (w, w), jnp.float32) * (1.0 / math.sqrt(w)),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": jax.random.normal(ks[4], (w, w), jnp.float32) * (1.0 / math.sqrt(w)),
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": jnp.ones((w,), jnp.float32),  # softplus(1) ~ 1.31 -> a in (0,1)
        "out": jax.random.normal(ks[5], (w, d), L.dt(cfg)) * (1.0 / math.sqrt(w)),
    }
    a = {
        "in_x": ("embed", "mlp"), "in_gate": ("embed", "mlp"),
        "conv": {"w": (None, "mlp"), "b": ("mlp",)},
        "wa": ("mlp", None), "ba": ("mlp",),
        "wx": ("mlp", None), "bx": ("mlp",),
        "lam": ("mlp",),
        "out": ("mlp", "embed"),
    }
    return p, a


def rglru_forward(cfg, p, u, cache=None):
    """u: [B, S, d]; cache: None or dict(conv [B,W-1,w], h [B,w] f32, pos).
    Returns (y, new_cache)."""
    B, S, d = u.shape
    gate = jax.nn.gelu(u @ p["in_gate"])
    x = u @ p["in_x"]
    conv_state = cache["conv"] if cache is not None else None
    x, new_conv = L.causal_conv1d(p["conv"], x, conv_state)

    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"] + p["ba"])
    i = jax.nn.sigmoid(xf @ p["wx"] + p["bx"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # [B,S,w], < 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * xf)

    h0 = cache["h"] if cache is not None else jnp.zeros((B, x.shape[-1]),
                                                        jnp.float32)
    if cache is not None and S == 1:
        h = a[:, 0] * h0 + b[:, 0]
        hs = h[:, None, :]
        new_h = h
    else:
        # associative scan over (a, b): (a2, b2) o (a1, b1) = (a1*a2, a2*b1+b2)
        # seed the first step with h0 by folding it into b[0].
        b = b.at[:, 0].add(a[:, 0] * h0)

        def comb(l, r_):
            return (l[0] * r_[0], r_[0] * l[1] + r_[1])

        _, hs = jax.lax.associative_scan(comb, (a, b), axis=1)
        new_h = hs[:, -1]

    y = (hs * gate.astype(jnp.float32)).astype(u.dtype) @ p["out"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "h": new_h, "pos": cache["pos"] + S}
    return y, new_cache


def init_rglru_cache(cfg, batch):
    w = cfg.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, w), L.dt(cfg)),
            "h": jnp.zeros((batch, w), jnp.float32),
            "pos": jnp.zeros((), jnp.int32)}
