"""Model configuration covering the 10 assigned architectures.

One generic decoder-LM config with per-layer block specs; modality
frontends (whisper audio, paligemma vision) are stubs per the assignment:
input_specs() provides precomputed frame/patch embeddings.

Layer stacking for scan-over-layers: `groups` is a tuple of
(pattern, repeats) — parameters of each pattern position are stacked
[repeats, ...] and the stack is scanned, keeping compiled HLO size
O(pattern) instead of O(n_layers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"            # "attn" | "rglru" | "ssd"
    window: Optional[int] = None  # sliding-window size; None = global attn
    mlp: str = "dense"            # "dense" | "moe" | "none"
    cross_attn: bool = False      # whisper decoder blocks


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder; frontend stubbed to precomputed embeddings."""
    n_layers: int
    n_frames: int                 # encoder sequence length (e.g. 1500)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    groups: tuple  # ((LayerSpec, ...), repeats), ...

    norm: str = "rmsnorm"         # "rmsnorm" | "layernorm"
    act: str = "silu"             # "silu" | "gelu"
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    embed_scale: bool = False     # gemma-style sqrt(d_model) input scaling
    logit_softcap: float = 0.0    # gemma-style tanh soft-cap (0 = off)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.01

    # attention implementation
    attn_impl: str = "gqa"        # "gqa" | "mla"
    mla_absorb: bool = False      # absorbed-matmul MLA decode (§Perf)
    q_lora_rank: int = 0          # MLA (deepseek-v3)
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 SSD)
    ssd_state: int = 0
    ssd_headdim: int = 64
    ssd_expand: int = 2
    ssd_chunk: int = 256
    conv_width: int = 4

    # RG-LRU (recurrentgemma)
    lru_width: int = 0

    # modality stubs
    encoder: Optional[EncoderConfig] = None   # whisper
    vlm_patches: int = 0                      # paligemma SigLIP stub

    # multi-token prediction (deepseek-v3)
    mtp: bool = False

    dtype: str = "bfloat16"
    vocab_pad: int = 256          # pad vocab for TP divisibility

    # --- derived ---
    @property
    def n_layers(self) -> int:
        return sum(len(pat) * rep for pat, rep in self.groups)

    @property
    def padded_vocab(self) -> int:
        v, p = self.vocab, self.vocab_pad
        return ((v + p - 1) // p) * p

    @property
    def ssd_d_inner(self) -> int:
        return self.ssd_expand * self.d_model

    @property
    def ssd_n_heads(self) -> int:
        return self.ssd_d_inner // self.ssd_headdim

    def layer_specs(self):
        """Flat per-layer spec list (order of execution)."""
        out = []
        for pat, rep in self.groups:
            for _ in range(rep):
                out.extend(pat)
        return out

    def supports_long_context(self) -> bool:
        """True iff every temporal-mixing block is sub-quadratic (windowed
        attention, SSD, or RG-LRU) — the long_500k gate in DESIGN.md §4,
        except gemma3 whose 1-in-6 global layers we accept (local layers
        dominate; global KV is sharded)."""
        for s in self.layer_specs():
            if s.kind == "attn" and s.window is None:
                return False
        return True


def param_count(cfg: ModelConfig) -> int:
    """Total parameters (for the 6·N·D roofline term)."""
    n = cfg.padded_vocab * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        n += cfg.padded_vocab * cfg.d_model
    n += _stack_params(cfg, active_only=False)
    n += cfg.d_model  # final norm
    if cfg.encoder is not None:
        enc_spec = LayerSpec(kind="attn", window=None, mlp="dense")
        n += cfg.encoder.n_layers * _layer_params(cfg, enc_spec, cross=False)
        n += cfg.d_model
    if cfg.mtp:
        n += 2 * cfg.d_model * cfg.d_model + _layer_params(
            cfg, cfg.layer_specs()[-1], cross=False)
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: only routed top-k + shared)."""
    n = cfg.padded_vocab * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.padded_vocab * cfg.d_model
    n += _stack_params(cfg, active_only=True)
    n += cfg.d_model
    if cfg.encoder is not None:
        enc_spec = LayerSpec(kind="attn", window=None, mlp="dense")
        n += cfg.encoder.n_layers * _layer_params(cfg, enc_spec, cross=False)
        n += cfg.d_model
    if cfg.mtp:
        n += 2 * cfg.d_model * cfg.d_model + _layer_params(
            cfg, cfg.layer_specs()[-1], cross=False, active_only=True)
    return n


def _stack_params(cfg: ModelConfig, active_only: bool) -> int:
    return sum(
        _layer_params(cfg, s, s.cross_attn, active_only)
        for s in cfg.layer_specs())


def _layer_params(cfg, spec: LayerSpec, cross: bool, active_only=False) -> int:
    d = cfg.d_model
    n = 0
    # temporal mixer
    if spec.kind == "attn":
        if cfg.attn_impl == "mla":
            qh = cfg.qk_nope_dim + cfg.qk_rope_dim
            n += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qh
            n += d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            n += cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
            n += cfg.n_heads * cfg.v_head_dim * d
        else:
            n += d * cfg.n_heads * cfg.head_dim          # q
            n += 2 * d * cfg.n_kv_heads * cfg.head_dim   # k, v
            n += cfg.n_heads * cfg.head_dim * d          # o
        n += d  # norm
        if cross:
            n += 2 * (d * cfg.n_heads * cfg.head_dim) + \
                2 * (d * cfg.n_kv_heads * cfg.head_dim) // 2 + d
    elif spec.kind == "ssd":
        di, ns, nh = cfg.ssd_d_inner, cfg.ssd_state, cfg.ssd_n_heads
        n += d * (2 * di + 2 * ns + nh)   # in_proj (x, z, B, C, dt)
        n += cfg.conv_width * (di + 2 * ns)
        n += 3 * nh                        # A, dt_bias, D
        n += di * d                        # out_proj
        n += d
    elif spec.kind == "rglru":
        w = cfg.lru_width or d
        n += d * w * 2 + cfg.conv_width * w + 2 * w + w * d + d
    # channel mixer
    if spec.mlp == "dense":
        mult = 3 if cfg.gated_mlp else 2
        n += mult * d * cfg.d_ff + d
    elif spec.mlp == "moe":
        mult = 3 if cfg.gated_mlp else 2
        e = (cfg.top_k if active_only else cfg.n_experts) + cfg.n_shared_experts
        n += e * mult * d * cfg.moe_d_ff + d * cfg.n_experts + d
    return n
