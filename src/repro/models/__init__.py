from repro.models.config import LayerSpec, ModelConfig, EncoderConfig, \
    param_count, active_param_count
from repro.models import lm, steps, sharding

__all__ = ["LayerSpec", "ModelConfig", "EncoderConfig", "param_count",
           "active_param_count", "lm", "steps", "sharding"]
