"""Train / serve step factories — the functions the launcher jits and the
dry-run lowers.

train_step: causal-LM loss (+ MoE aux, + MTP for deepseek), global-norm
clip, optimizer update, gradient-accumulation microbatching (scan) for
compute/collective overlap.

serve steps: prefill (build caches, return last logits) and decode (one
token against the caches) — `decode_*`/`long_*` shapes lower serve_step,
not train_step.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import clip_by_global_norm


def loss_fn(cfg: ModelConfig, params, batch, impl="blockwise"):
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch.get("mask")
    out = lm.forward(
        cfg, params, tokens,
        patches=batch.get("patches"), frames=batch.get("frames"),
        impl=impl, return_hidden=cfg.mtp)
    if cfg.mtp:
        logits, _, aux, hidden = out
    else:
        logits, _, aux = out
        hidden = None
    if cfg.vlm_patches:
        logits = logits[:, cfg.vlm_patches:]
        if hidden is not None:
            hidden = hidden[:, cfg.vlm_patches:]

    nll = _xent(cfg, logits, labels, mask)
    loss = nll + cfg.router_aux_coef * aux

    metrics = {"nll": nll, "aux": aux}
    if cfg.mtp and hidden is not None:
        # predict t+2 from hidden_t + embed(token_{t+1})
        nxt = L.embed(cfg, params["embed"], tokens[:, 1:])
        h = hidden[:, :-1]
        pos = jnp.arange(h.shape[1], dtype=jnp.int32)
        mlogits = lm.mtp_logits(cfg, params, h, nxt, pos, impl="blockwise")
        mlabels = labels[:, 1:]
        mmask = None if mask is None else mask[:, 1:]
        mtp_nll = _xent(cfg, mlogits, mlabels, mmask)
        loss = loss + 0.3 * mtp_nll
        metrics["mtp_nll"] = mtp_nll
    metrics["loss"] = loss
    return loss, metrics


def _xent(cfg, logits, labels, mask):
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_train_step(cfg: ModelConfig, opt_update, *, microbatches: int = 1,
                    clip_norm: float = 1.0, impl: str = "blockwise",
                    compress_fn=None):
    """Returns train_step(params, opt_state, step, batch) ->
    (params, opt_state, metrics).

    microbatches > 1 accumulates gradients with lax.scan — the standard
    overlap trick: each microbatch's reduce-scatter overlaps the next
    microbatch's compute under XLA latency-hiding scheduling.
    compress_fn (optional) transforms grads before the optimizer — the
    inter-pod gradient-compression hook (repro.optim.compression).
    """

    def train_step(params, opt_state, step, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                functools.partial(loss_fn, cfg, impl=impl), has_aux=True
            )(params, batch)
        else:
            def mb(carry, mbatch):
                acc = carry
                (_, m), g = jax.value_and_grad(
                    functools.partial(loss_fn, cfg, impl=impl), has_aux=True
                )(params, mbatch)
                acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
                return acc, m

            split = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, ms = jax.lax.scan(mb, zero, split)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], ms)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        if compress_fn is not None:
            grads = compress_fn(grads)
        updates, opt_state = opt_update(grads, opt_state, params, step)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                              updates)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: Optional[int] = None,
                      impl: str = "blockwise"):
    """prefill(params, tokens, caches, patches/frames) ->
    (last_logits [B, V], caches)."""

    def prefill(params, tokens, caches, patches=None, frames=None):
        logits, caches, _ = lm.forward(
            cfg, params, tokens, caches=caches, patches=patches,
            frames=frames, impl=impl)
        return logits[:, -1], caches

    return prefill


def make_decode_step(cfg: ModelConfig, impl: str = "blockwise"):
    """decode(params, caches, token [B,1], pos []) -> (logits [B,V], caches).
    This is `serve_step` for the decode_* / long_* shapes: one new token
    against a KV/state cache of seq_len."""

    def decode(params, caches, token, pos):
        # pos: [] (synchronized batch) or [B] (ragged continuous batching)
        positions = pos[..., None].astype(jnp.int32)
        # VLM prefix offsets positions by the patch count
        if cfg.vlm_patches:
            positions = positions + cfg.vlm_patches
        logits, caches, _ = lm.forward(
            cfg, params, token, positions=positions, caches=caches, impl=impl)
        return logits[:, -1], caches

    return decode
