"""Logical-axis sharding for the production mesh.

Every parameter is initialized alongside a tuple of *logical* axis names
(models.layers/attention/moe/ssd/rglru).  This module maps logical names
to mesh axes with divisibility-aware fallbacks:

  * at most one mesh axis is consumed per tensor per mesh-axis name;
  * a logical dim is sharded only if its size divides the mesh axis size —
    otherwise it falls back to the next candidate dim (e.g. mixtral's 8
    experts don't divide a 16-way model axis, so the expert FFN shards its
    "mlp" dim instead; paligemma's 8 heads fall back to "mlp"/"vocab");
  * optional FSDP: the largest still-unsharded dim of large tensors is
    additionally sharded over the data axis (ZeRO-3-style), required for
    deepseek-v3/mixtral to fit HBM;
  * activations are constrained through `constrain(x, logical_axes)`
    using the same rules ("batch" -> ("pod","data"), etc.).

Rules are held in a module-level context installed by the launcher /
dry-run around tracing, so model code stays framework-free.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# priority order: earlier logical names grab the "model" axis first.
# "embed" is the last-resort fallback (§Perf iteration: without it, tensors
# whose natural dims don't divide the axis — e.g. deepseek's wo at 256-way
# 2D TP — replicate and blow the HBM budget).
MODEL_AXIS_PRIORITY = ("experts", "vocab", "heads", "kv_heads", "mlp",
                       "lora", "head_dim", "embed")


@dataclasses.dataclass(frozen=True)
class Rules:
    batch: tuple = ("pod", "data")       # filtered by mesh axis presence
    seq: tuple = ()                      # ("data",) enables sequence sharding
    model: tuple = ("model",)
    fsdp: tuple = ("data",)              # axis used for FSDP param sharding
    fsdp_params: bool = False            # shard big params over data axis
    fsdp_min_size: int = 1 << 20         # only tensors >= 1M elements
    moe_constraints: bool = False        # constrain MoE dispatch tensors
                                         # (beyond-paper §Perf optimization)
    moe_shard_map: bool = False          # shard_map expert path: dispatch
                                         # stays local per data shard
    shard_experts: bool = True           # False: skip expert-dim sharding
                                         # (shard_map path needs the full
                                         # expert set on every device)


DEFAULT_RULES = Rules()

_CTX: dict = {"mesh": None, "rules": DEFAULT_RULES}


def set_context(mesh: Optional[Mesh], rules: Rules = DEFAULT_RULES):
    _CTX["mesh"], _CTX["rules"] = mesh, rules


def get_context():
    return _CTX["mesh"], _CTX["rules"]


def _axes_in_mesh(mesh, names):
    return tuple(n for n in names if n in mesh.axis_names)


def _axis_size(mesh, names) -> int:
    return int(np.prod([mesh.shape[n] for n in names])) if names else 1


def spec_for_param(mesh, rules: Rules, logical_axes, shape) -> P:
    """PartitionSpec for one parameter tensor."""
    model_ax = _axes_in_mesh(mesh, rules.model)
    model_sz = _axis_size(mesh, model_ax)
    assign: dict[int, tuple] = {}

    # 1) model-axis dim: first by priority that divides.  The "embed"
    # last-resort fallback only applies to large tensors (it exists to
    # stop multi-GB weights from replicating, not to scatter small
    # routers/norms whose replication is free).
    big = int(np.prod(shape)) >= (1 << 22)
    for name in MODEL_AXIS_PRIORITY:
        if name == "embed" and not big:
            continue
        if name == "experts" and not rules.shard_experts:
            continue
        done = False
        for i, ax in enumerate(logical_axes):
            if ax == name and shape[i] % model_sz == 0 and model_sz > 1:
                assign[i] = model_ax
                done = True
                break
        if done:
            break

    # 2) FSDP: largest unassigned dim over the data axis
    if rules.fsdp_params and int(np.prod(shape)) >= rules.fsdp_min_size:
        data_ax = _axes_in_mesh(mesh, rules.fsdp)
        data_sz = _axis_size(mesh, data_ax)
        if data_sz > 1:
            cands = [i for i in range(len(shape)) if i not in assign
                     and shape[i] % data_sz == 0]
            if cands:
                big = max(cands, key=lambda i: shape[i])
                assign[big] = data_ax
    return P(*[assign.get(i, None) for i in range(len(shape))])


def make_param_shardings(mesh, rules: Rules, axes_tree, shapes_tree):
    """NamedSharding pytree matching the params pytree."""
    def one(ax, shp):
        shape = shp.shape if hasattr(shp, "shape") else shp
        return NamedSharding(mesh, spec_for_param(mesh, rules, ax, shape))
    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def spec_for_act(mesh, rules: Rules, logical_axes, shape=None) -> P:
    out = []
    used = set()
    for i, name in enumerate(logical_axes):
        if name == "batch":
            ax = _axes_in_mesh(mesh, rules.batch)
        elif name == "seq":
            ax = _axes_in_mesh(mesh, rules.seq)
        elif name in ("heads", "kv_heads", "experts", "mlp", "vocab"):
            ax = _axes_in_mesh(mesh, rules.model)
        else:
            ax = ()
        ax = tuple(a for a in ax if a not in used)
        if ax and shape is not None and shape[i] % _axis_size(mesh, ax) != 0:
            ax = ()
        used |= set(ax)
        out.append(ax if ax else None)
    return P(*out)


def constrain(x, logical_axes):
    """with_sharding_constraint under the installed mesh context (no-op
    outside a mesh context, so unit tests run untouched)."""
    mesh, rules = get_context()
    if mesh is None:
        return x
    spec = spec_for_act(mesh, rules, logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def put_on_device(tree, device):
    """Commit a pytree of arrays to ONE device (explicit per-device
    placement, the serving-arena counterpart of the logical-axis rules
    above).  The D-sharded executor (core/sharded.py) places each shard's
    arena with this; every later op on the shard — jit dispatch included
    — follows the committed placement, so uncommitted host uploads never
    drag a shard back to the default device.  None = leave uncommitted
    (single-device serving keeps its historical placement)."""
    if device is None:
        return tree
    return jax.device_put(tree, device)
