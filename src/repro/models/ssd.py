"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Train/prefill uses the chunked SSD algorithm: within-chunk quadratic
(attention-like) term + across-chunk linear recurrence over chunk states —
O(S * chunk) work, MXU-friendly einsums.  Decode is the O(1) recurrent
step on a [B, H, P, N] state (the long_500k enabler for this arch).

Scalar-per-head A (SSD restriction), single B/C group, depthwise causal
conv on (x, B, C) as in the reference implementation.  dt/decay math in
fp32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def init_ssd(cfg, key):
    d = cfg.d_model
    di, ns, nh = cfg.ssd_d_inner, cfg.ssd_state, cfg.ssd_n_heads
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    conv_ch = di + 2 * ns
    p = {
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * di + 2 * ns + nh), L.dt(cfg)) * s,
        "conv": {"w": jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                        jnp.float32) * 0.1,
                 "b": jnp.zeros((conv_ch,), jnp.float32)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (di, d), L.dt(cfg)) * (1.0 / math.sqrt(di)),
    }
    a = {
        "in_proj": ("embed", "mlp"),
        "conv": {"w": (None, "mlp"), "b": ("mlp",)},
        "A_log": (None,), "dt_bias": (None,), "D": (None,),
        "out_proj": ("mlp", "embed"),
    }
    return p, a


def _split(cfg, zxbcdt):
    di, ns, nh = cfg.ssd_d_inner, cfg.ssd_state, cfg.ssd_n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * ns]
    dt_raw = zxbcdt[..., 2 * di + 2 * ns :]
    return z, xbc, dt_raw


def ssd_forward(cfg, p, u, cache=None):
    """u: [B, S, d].  cache: None or dict(conv [B,W-1,C], state [B,H,P,N],
    pos).  Returns (y, new_cache)."""
    B, S, d = u.shape
    di, ns, nh, hp = cfg.ssd_d_inner, cfg.ssd_state, cfg.ssd_n_heads, cfg.ssd_headdim

    zxbcdt = u @ p["in_proj"]
    z, xbc, dt_raw = _split(cfg, zxbcdt)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = L.causal_conv1d(p["conv"], jax.nn.silu(xbc), conv_state)
    x, Bm, Cm = (xbc[..., :di],
                 xbc[..., di : di + ns],
                 xbc[..., di + ns :])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    A = -jnp.exp(p["A_log"])                                          # [H]
    xh = x.reshape(B, S, nh, hp)

    if cache is not None and S == 1:
        # ---- recurrent decode step ----
        st = cache["state"]                                   # [B,H,P,N] f32
        a_t = jnp.exp(dt[:, 0, :] * A)                        # [B,H]
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        st = st * a_t[:, :, None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", st, Cm[:, 0].astype(jnp.float32))
        y = y + p["D"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = (y.reshape(B, 1, di) * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
        out = y @ p["out_proj"]
        return out, {"conv": new_conv, "state": st, "pos": cache["pos"] + 1}

    # ---- chunked SSD scan (train / prefill) ----
    ck = min(cfg.ssd_chunk, max(S, 1))
    nchunk = -(-S // ck)
    pad = nchunk * ck - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    xc = xh.reshape(B, nchunk, ck, nh, hp).astype(jnp.float32)
    Bc = Bm.reshape(B, nchunk, ck, ns).astype(jnp.float32)
    Cc = Cm.reshape(B, nchunk, ck, ns).astype(jnp.float32)
    dtc = dt.reshape(B, nchunk, ck, nh)

    la = dtc * A                                              # log a_t [B,c,l,H]
    seg = jnp.cumsum(la, axis=2)                              # within-chunk cumsum
    # intra-chunk (quadratic in ck): L_ij = exp(seg_i - seg_j + la_j? ) care:
    # decay from step j+1..i applied to contribution injected at j.
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]        # [B,c,i,j,H]
    causal = jnp.tril(jnp.ones((ck, ck), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # [B,c,i,j]
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                         cb, Lmat, dtc, xc)

    # chunk states: S_c = sum_j exp(seg_last - seg_j) * dt_j * B_j x_j^T
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)           # [B,c,l,H]
    states = jnp.einsum("bclh,bclh,bcln,bclhp->bchpn",
                        decay_to_end, dtc, Bc, xc)            # [B,c,H,P,N]
    chunk_decay = jnp.exp(seg[:, :, -1, :])                   # [B,c,H]

    init = cache["state"] if cache is not None else jnp.zeros(
        (B, nh, hp, ns), jnp.float32)

    def scan_fn(carry, inp):
        st_c, dec = inp
        new = carry * dec[:, :, None, None] + st_c
        return new, carry                                     # emit state BEFORE chunk

    statesT = states.transpose(1, 0, 2, 3, 4)
    decayT = chunk_decay.transpose(1, 0, 2)
    final_state, prev_states = jax.lax.scan(scan_fn, init, (statesT, decayT))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # [B,c,H,P,N]

    # inter-chunk: y_i += C_i . (decay_from_start_i * S_prev)
    decay_in = jnp.exp(seg)                                   # [B,c,l,H]
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         Cc, decay_in, prev_states)

    y = y_intra + y_inter + p["D"][None, None, None, :, None] * xc
    y = y.reshape(B, nchunk * ck, di)[:, :S]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": final_state,
                     "pos": cache["pos"] + S}
    return out, new_cache


def init_ssd_cache(cfg, batch):
    di, ns, nh, hp = cfg.ssd_d_inner, cfg.ssd_state, cfg.ssd_n_heads, cfg.ssd_headdim
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * ns), L.dt(cfg)),
        "state": jnp.zeros((batch, nh, hp, ns), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }
