"""Shared helpers for the UCT Pallas kernels.

Memory layout (TPU adaptation of the paper's SRAM banking, §IV-B):

The paper stores the UCT as a compact adjacency list in per-level SRAM
banks sized for single-cycle access.  On TPU the analogue is VMEM
residency with VPU-aligned rows: every ``[X, Fp]`` edge-statistic array is
packed into ``[X*Fp/128, 128]`` int32 so that

  * a node's Fp-edge block lives in ONE 128-lane VMEM row (Fp is a power
    of two <= 128, so blocks never straddle rows) — one vector load plays
    the role of the paper's one-cycle bank read;
  * the selection comparator is a masked 128-lane argmax — the VPU-native
    replacement of the paper's CLUT comparator tree (§IV-D), which has no
    TPU analogue;
  * updates are full-row read-modify-writes (no sub-lane dynamic stores,
    which Mosaic lowers poorly).

Node-indexed ``[X]`` arrays are packed into ``[ceil(X/128), 128]`` rows and
accessed with the same row RMW discipline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def padded_x(x: int, fp: int) -> int:
    """Smallest X' >= x with X'*Fp a multiple of 128."""
    step = max(1, LANES // fp)
    return ((x + step - 1) // step) * step


def pack_edges(arr, fp: int):
    """[X, Fp] -> [Xp*Fp/128, 128] (row-aligned node blocks)."""
    x = arr.shape[0]
    xp_ = padded_x(x, fp)
    if xp_ != x:
        arr = jnp.concatenate(
            [arr, jnp.zeros((xp_ - x, fp), arr.dtype)], axis=0)
    return arr.reshape(xp_ * fp // LANES, LANES)


def unpack_edges(packed, x: int, fp: int):
    return packed.reshape(-1, fp)[:x]


def pack_nodes(arr):
    """[X] -> [ceil(X/128), 128]."""
    x = arr.shape[0]
    xp_ = ((x + LANES - 1) // LANES) * LANES
    if xp_ != x:
        arr = jnp.concatenate([arr, jnp.zeros((xp_ - x,), arr.dtype)])
    return arr.reshape(xp_ // LANES, LANES)


def unpack_nodes(packed, x: int):
    return packed.reshape(-1)[:x]


# ---- arena packing: a leading [G] slot axis over the same row layout ----
#
# The arena kernels block-map one slot per grid program, so the packed
# layout just gains a leading G axis: [G, X, Fp] -> [G, X*Fp/128, 128].
# vmap of the single-tree helpers keeps the two layouts one definition.

def pack_edges_arena(arr, fp: int):
    """[G, X, Fp] -> [G, Xp*Fp/128, 128]."""
    return jax.vmap(lambda a: pack_edges(a, fp))(arr)


def unpack_edges_arena(packed, x: int, fp: int):
    return jax.vmap(lambda a: unpack_edges(a, x, fp))(packed)


def pack_nodes_arena(arr):
    """[G, X] -> [G, ceil(X/128), 128]."""
    return jax.vmap(pack_nodes)(arr)


def unpack_nodes_arena(packed, x: int):
    return jax.vmap(lambda a: unpack_nodes(a, x))(packed)


# ---- in-kernel access helpers (all row-granular) -------------------------

def canonical_index(i):
    """dynamic_slice / dslice starts must all share one dtype, and literal
    starts (the 0 hidden in a full slice) canonicalize to jax's index
    dtype — i64 under JAX_ENABLE_X64, i32 otherwise.  Traced starts must
    follow, or mixed index tuples fail to trace under the x64 CI leg."""
    dt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    return jnp.asarray(i, dt)


def lane_iota():
    """[1, 128] lane indices (2-D: 1-D iota does not lower on TPU)."""
    return jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)


def load_row(ref, row):
    """One 128-lane row as [1, 128]."""
    return pl.load(ref, (pl.dslice(canonical_index(row), 1), slice(None)))


def store_row(ref, row, val):
    pl.store(ref, (pl.dslice(canonical_index(row), 1), slice(None)), val)


def sload(ref, idx):
    """Scalar load from a packed node array."""
    row = load_row(ref, idx // LANES)
    return jax.lax.dynamic_slice(
        row, (canonical_index(0), canonical_index(idx % LANES)), (1, 1)
    )[0, 0]


def sadd(ref, idx, inc):
    """Scalar add via full-row RMW (vectorized select, no sub-lane store)."""
    row_i = idx // LANES
    row = load_row(ref, row_i)
    upd = jnp.where(lane_iota() == (idx % LANES), inc, 0).astype(row.dtype)
    store_row(ref, row_i, row + upd)


def extract_lane(vec_1x128, lane):
    """vec[0, lane] for traced lane index."""
    return jax.lax.dynamic_slice(
        vec_1x128, (canonical_index(0), canonical_index(lane)), (1, 1))[0, 0]
