"""Pallas TPU kernels for the paper's compute hot-spots.

  uct_select.py      — Tree-Parallel Selection + virtual loss (paper §IV)
  uct_backup.py      — BackUp from memoized paths (paper §IV-E)
  flash_attention.py — LM simulation-backend prefill attention
  ops.py             — jit wrappers matching repro.core.intree's API
  ref.py             — pure-jnp oracles (transitively bit-exact vs the
                       sequential CPU program)

Kernels target the TPU backend and are validated with interpret=True on
CPU (this container has no TPU).
"""
