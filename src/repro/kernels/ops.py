"""jit'd wrappers exposing the Pallas kernels through the same API as
repro.core.intree, so the BSP driver can swap executors freely
(executor="pallas").

Kernels run in interpret mode by default (this container is CPU-only; the
TPU backend is the compilation target).  Pass interpret=False on real TPU.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import intree
from repro.core.tree import TreeConfig, UCTree
from repro.kernels import uct_backup, uct_select

INTERPRET = True  # flipped to False on a real TPU deployment


def select_batch(cfg: TreeConfig, tree: UCTree, p: int):
    """Selection + Node-Insertion assignment; mirrors intree.select_batch."""
    evl, no, pn, pa, depths, leaves = uct_select.select(
        cfg, tree, p, interpret=INTERPRET)
    tree = dataclasses.replace(tree, edge_VL=evl, node_O=no)
    return intree._assign_expansions(cfg, tree, pn, pa, depths, leaves, p)


def backup_batch(cfg: TreeConfig, tree: UCTree, sel, sim_nodes, values_fx,
                 alternating_signs: bool = False):
    """BackUp; mirrors intree.backup_batch."""
    p = sel.leaves.shape[0]
    en, ew, evl, nn, no = uct_backup.backup(
        cfg, tree, sel.path_nodes, sel.path_actions,
        jnp.asarray(sel.depths), jnp.asarray(sel.leaves),
        jnp.asarray(sel.expand_action), jnp.asarray(sim_nodes, jnp.int32),
        jnp.asarray(values_fx, jnp.int32), p=p,
        alternating=alternating_signs, interpret=INTERPRET)
    return dataclasses.replace(
        tree, edge_N=en, edge_W=ew, edge_VL=evl, node_N=nn, node_O=no)
