"""jit'd wrappers exposing the Pallas kernels through the same API as
repro.core.intree, so the unified executor stack (core.executor) can swap
the kernels in freely (executor="pallas") — single-tree and arena alike.

The arena entry points (`select_arena` / `backup_arena`) drive the
[G]-grid kernels: one launch covers every tree slot, inactive slots no-op
inside the kernel (no where_trees post-select needed), and the expansion
assignment post-pass runs vmapped on the jit path exactly as the jax
arena executor does.

Kernels run in interpret mode by default (this container is CPU-only; the
TPU backend is the compilation target).  Pass interpret=False on real TPU.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import intree
from repro.core.tree import TreeConfig, UCTree
from repro.kernels import uct_backup, uct_select

INTERPRET = True  # flipped to False on a real TPU deployment


def select_batch(cfg: TreeConfig, tree: UCTree, p: int):
    """Selection + Node-Insertion assignment; mirrors intree.select_batch."""
    evl, no, pn, pa, depths, leaves = uct_select.select(
        cfg, tree, p, interpret=INTERPRET)
    tree = dataclasses.replace(tree, edge_VL=evl, node_O=no)
    return intree._assign_expansions(cfg, tree, pn, pa, depths, leaves, p)


def backup_batch(cfg: TreeConfig, tree: UCTree, sel, sim_nodes, values_fx,
                 alternating_signs: bool = False):
    """BackUp; mirrors intree.backup_batch."""
    p = sel.leaves.shape[0]
    en, ew, evl, nn, no = uct_backup.backup(
        cfg, tree, sel.path_nodes, sel.path_actions,
        jnp.asarray(sel.depths), jnp.asarray(sel.leaves),
        jnp.asarray(sel.expand_action), jnp.asarray(sim_nodes, jnp.int32),
        jnp.asarray(values_fx, jnp.int32), p=p,
        alternating=alternating_signs, interpret=INTERPRET)
    return dataclasses.replace(
        tree, edge_N=en, edge_W=ew, edge_VL=evl, node_N=nn, node_O=no)


@functools.partial(jax.jit, static_argnums=(0, 3))
def _assign_expansions_arena(cfg: TreeConfig, arena: UCTree, sel_raw,
                             p: int):
    pn, pa, depths, leaves = sel_raw
    _, sel = jax.vmap(
        lambda t, n, a, d, l: intree._assign_expansions(cfg, t, n, a, d, l, p)
    )(arena, pn, pa, depths, leaves)
    return sel


def select_arena(cfg: TreeConfig, arena: UCTree, active, p: int):
    """Arena Selection; mirrors intree.select_arena.  Returns
    (arena', sel[G, ...]).  The kernel freezes inactive slots itself, so
    the returned arena needs no mask post-select; their sel rows are dead
    data the host ignores (same contract as the jax arena path)."""
    evl, no, pn, pa, depths, leaves = uct_select.select_arena(
        cfg, arena, jnp.asarray(active, jnp.int32), p, interpret=INTERPRET)
    arena = dataclasses.replace(arena, edge_VL=evl, node_O=no)
    sel = _assign_expansions_arena(cfg, arena, (pn, pa, depths, leaves), p)
    return arena, sel


def backup_arena(cfg: TreeConfig, arena: UCTree, active, sel, sim_nodes,
                 values_fx, alternating_signs: bool = False):
    """Arena BackUp; mirrors intree.backup_arena (fault-free path)."""
    p = sel.leaves.shape[1]
    en, ew, evl, nn, no = uct_backup.backup_arena(
        cfg, arena, jnp.asarray(active, jnp.int32),
        sel.path_nodes, sel.path_actions,
        jnp.asarray(sel.depths), jnp.asarray(sel.leaves),
        jnp.asarray(sel.expand_action), jnp.asarray(sim_nodes, jnp.int32),
        jnp.asarray(values_fx, jnp.int32), p=p,
        alternating=alternating_signs, interpret=INTERPRET)
    return dataclasses.replace(
        arena, edge_N=en, edge_W=ew, edge_VL=evl, node_N=nn, node_O=no)
