"""Pure-jnp oracles for the Pallas kernels.

The reference chain is two layers deep, both tested:
  Pallas kernels (this package)  ==  intree batched jnp ops (this module)
  intree batched jnp ops         ==  ref_sequential numpy CPU program

so kernels are transitively bit-exact against the paper's sequential
baseline.  The re-exports below are the "ref.py pure-jnp oracle" contract
for the per-kernel sweep tests.
"""

from repro.core.intree import (
    backup_batch as backup_ref,
    select_batch as select_ref,
)

__all__ = ["select_ref", "backup_ref"]
