"""Pallas TPU kernel: Tree-Parallel Selection + virtual-loss apply.

This is the accelerator core of the paper (§IV-B/C/D) adapted to TPU:

  paper FPGA                         | this kernel
  -----------------------------------+----------------------------------
  per-level SRAM banks, 1-cycle read | UCT packed row-aligned in VMEM
  subtree pipelines (1 worker/stage) | fori_loop over workers: identical
                                     | ordering semantics, VMEM-resident
  CLUT comparator tree at the root   | masked 128-lane VPU argmax
  fixed-point single-cycle compare   | Qm.16 int32 scores (exact compare)
  backup memoization buffer          | path_nodes/path_actions outputs

The whole UCT (all edge/node statistic arrays) is one VMEM working set —
"T_mem = 1 cycle" becomes "zero HBM traffic after tile load".  Worker
ordering is preserved exactly (worker k sees the virtual loss of workers
< k), so outputs are bit-identical to the sequential CPU program; the
kernel shares the scoring spec of repro.core.scoring verbatim.

Arena-native: the kernel runs on a ``[G]`` grid — one program per tree
slot, that slot's packed UCT arrays block-mapped into VMEM — so G
independent searches (the service layer's arena) cost ONE kernel launch.
Per-slot scalars (root id, tree size, active flag) ride in an SMEM
scalar-prefetch operand; an inactive slot's program is a no-op (the
aliased buffers pass through untouched), which keeps parked trees
bit-frozen.  Single-tree selection is the G=1 case.

The kernel is written for the TPU backend (2-D iotas, row-granular RMW,
power-of-two edge blocks) and validated in interpret mode on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import scoring
from repro.core.tree import NULL, TreeConfig
from repro.kernels import common as cm

LANES = cm.LANES

# meta layout: one SMEM row of per-slot scalars, prefetched before the
# grid program runs (paper: the accelerator's per-tree control registers).
# META_SIZE is reserved: the kernels read the whole block-mapped slot, but
# the TPU build will use the live tree size to bound the DMA'd prefix of
# the statistic arrays instead of shipping all X rows per slot.
META_ROOT, META_SIZE, META_ACTIVE = 0, 1, 2
META_WORDS = 3


def _select_kernel(
    # scalar prefetch
    meta_ref,        # [G, 3] i32 in SMEM: (root, size, active) per slot
    # inputs (per-slot VMEM blocks)
    child_ref,       # [Er, 128] i32 packed edges
    edge_n_ref,      # [Er, 128] i32
    edge_w_ref,      # [Er, 128] i32 (Qm.16)
    edge_p_ref,      # [Er, 128] i32 (Qm.16)
    node_n_ref,      # [Nr, 128] i32 packed nodes
    num_exp_ref,     # [Nr, 128] i32
    num_act_ref,     # [Nr, 128] i32
    terminal_ref,    # [Nr, 128] i32
    log_ref,         # [Lr, 128] f32 packed ln table
    evl_in_ref,      # [Er, 128] i32 (aliased with edge_vl_ref)
    no_in_ref,       # [Nr, 128] i32 (aliased with node_o_ref)
    # outputs (per-slot VMEM blocks)
    edge_vl_ref,     # [Er, 128] i32
    node_o_ref,      # [Nr, 128] i32
    pn_ref,          # [p, D] i32
    pa_ref,          # [p, D] i32
    depth_ref,       # [1, p] i32
    leaf_ref,        # [1, p] i32
    *,
    cfg: TreeConfig,
    p: int,
):
    Fp, D = cfg.Fp, cfg.D
    lane = cm.lane_iota()
    i32 = jnp.int32
    g = pl.program_id(0)
    root = meta_ref[g, META_ROOT]
    slot_active = meta_ref[g, META_ACTIVE]

    # Aliased buffers: physically a no-op copy; keeps the kernel correct
    # when run un-aliased (e.g. some interpret configurations).
    edge_vl_ref[...] = evl_in_ref[...]
    node_o_ref[...] = no_in_ref[...]
    # init path outputs to NULL
    pn_ref[...] = jnp.full((p, D), NULL, i32)
    pa_ref[...] = jnp.full((p, D), NULL, i32)
    depth_ref[...] = jnp.zeros((1, p), i32)
    leaf_ref[...] = jnp.zeros((1, p), i32)

    def worker(j, _):
        cm.sadd(node_o_ref, root, 1)

        def level(d, carry):
            node, depth = carry
            n_exp = cm.sload(num_exp_ref, node)
            n_act = cm.sload(num_act_ref, node)
            term = cm.sload(terminal_ref, node)
            leafp = scoring.is_leaf(
                cfg, num_expanded=n_exp, num_actions=n_act,
                terminal=term, depth=depth, xp=jnp)
            active = (~leafp) & (d == depth)

            row = node * Fp // LANES
            off = node * Fp % LANES
            child_r = cm.load_row(child_ref, row)
            seg = (lane >= off) & (lane < off + Fp)
            child_m = jnp.where(seg, child_r, NULL)

            n_n = cm.sload(node_n_ref, node)
            n_o = cm.sload(node_o_ref, node)
            ns = n_n + n_o if cfg.vl_mode == "wu" else n_n
            ns = jnp.minimum(ns, i32(2 * cfg.X + 3))
            log_ns = cm.sload(log_ref, ns)

            scores = scoring.edge_scores_fx(
                cfg,
                child=child_m,
                edge_N=cm.load_row(edge_n_ref, row),
                edge_W=cm.load_row(edge_w_ref, row),
                edge_VL=cm.load_row(edge_vl_ref, row),
                edge_P=cm.load_row(edge_p_ref, row),
                node_N=n_n[None, None],
                node_O=n_o[None, None],
                num_actions=(off + n_act)[None, None],
                xp=jnp,
                lane=lane,                      # lane < off + n_act validity
                log_ns=log_ns[None, None],
            )
            # VPU-native worker distributor (paper's CLUT, §IV-D): masked
            # first-max argmax over the full 128-lane row, as two 2-D
            # reductions (max, then min-index-of-max) — Mosaic-friendly.
            m = jnp.max(scores)
            g_ = jnp.min(jnp.where(scores == m, lane, i32(LANES))).astype(i32)

            # virtual-loss apply (Alg. 1 line 5) — row RMW
            vl_row = cm.load_row(edge_vl_ref, row)
            inc = jnp.where(active & (lane == g_), i32(1), i32(0))
            cm.store_row(edge_vl_ref, row, vl_row + inc)

            # memoization buffer write (paper §IV-E)
            d_lane = jax.lax.broadcasted_iota(i32, (1, D), 1)
            pn_row = pl.load(pn_ref, (pl.dslice(j, 1), slice(None)))
            pa_row = pl.load(pa_ref, (pl.dslice(j, 1), slice(None)))
            sel_d = active & (d_lane == d)
            pl.store(pn_ref, (pl.dslice(j, 1), slice(None)),
                     jnp.where(sel_d, node, pn_row))
            pl.store(pa_ref, (pl.dslice(j, 1), slice(None)),
                     jnp.where(sel_d, g_ - off, pa_row))

            nxt = cm.extract_lane(child_m, g_)
            node = jnp.where(active, nxt, node)
            cm.sadd(node_o_ref, node, jnp.where(active, i32(1), i32(0)))
            depth = depth + jnp.where(active, i32(1), i32(0))
            return node, depth

        node, depth = jax.lax.fori_loop(0, D, level, (root, i32(0)))
        dep_row = pl.load(depth_ref, (slice(None), slice(None)))
        leaf_row = pl.load(leaf_ref, (slice(None), slice(None)))
        sel_j = jax.lax.broadcasted_iota(i32, (1, p), 1) == j
        pl.store(depth_ref, (slice(None), slice(None)),
                 jnp.where(sel_j, depth, dep_row))
        pl.store(leaf_ref, (slice(None), slice(None)),
                 jnp.where(sel_j, node, leaf_row))
        return 0

    # inactive slot -> no-op program: the pass-through copies above leave
    # the tree statistics bit-identical and the path outputs are dead rows
    @pl.when(slot_active == 1)
    def _run_workers():
        jax.lax.fori_loop(0, p, worker, 0)


@functools.partial(jax.jit, static_argnames=("cfg", "p", "interpret"))
def select_arena(cfg: TreeConfig, arena, active, p: int,
                 interpret: bool = True):
    """Selection kernel over a G-slot arena (one grid program per slot).

    `arena` is a UCTree whose leaves carry a leading [G] axis; `active` is
    a [G] mask (bool or i32).  Returns (edge_VL', node_O', path_nodes,
    path_actions, depths, leaves) with logical (unpacked) shapes
    [G, X, Fp] / [G, X] / [G, p, D] / [G, p].  Inactive slots come back
    bit-identical with NULL/zero path rows.
    """
    Fp, D = cfg.Fp, cfg.D
    G, X = arena.child.shape[0], arena.child.shape[1]
    child_p = cm.pack_edges_arena(arena.child, Fp)
    en_p = cm.pack_edges_arena(arena.edge_N, Fp)
    ew_p = cm.pack_edges_arena(arena.edge_W, Fp)
    ep_p = cm.pack_edges_arena(arena.edge_P, Fp)
    evl_p = cm.pack_edges_arena(arena.edge_VL, Fp)
    nn_p = cm.pack_nodes_arena(arena.node_N)
    no_p = cm.pack_nodes_arena(arena.node_O)
    ne_p = cm.pack_nodes_arena(arena.num_expanded)
    na_p = cm.pack_nodes_arena(arena.num_actions)
    tm_p = cm.pack_nodes_arena(arena.terminal)
    lg_p = cm.pack_nodes_arena(arena.log_table)
    meta = jnp.stack(
        [jnp.asarray(arena.root, jnp.int32),
         jnp.asarray(arena.size, jnp.int32),
         jnp.asarray(active, jnp.int32)], axis=1)          # [G, 3]

    er, nr, lr = child_p.shape[1], nn_p.shape[1], lg_p.shape[1]
    slot = lambda *shp: pl.BlockSpec((None,) + shp,
                                     lambda g, m: (g,) + (0,) * len(shp))
    out_shapes = (
        jax.ShapeDtypeStruct((G, er, LANES), jnp.int32),   # edge_VL'
        jax.ShapeDtypeStruct((G, nr, LANES), jnp.int32),   # node_O'
        jax.ShapeDtypeStruct((G, p, D), jnp.int32),        # path_nodes
        jax.ShapeDtypeStruct((G, p, D), jnp.int32),        # path_actions
        jax.ShapeDtypeStruct((G, 1, p), jnp.int32),        # depths
        jax.ShapeDtypeStruct((G, 1, p), jnp.int32),        # leaves
    )
    kernel = functools.partial(_select_kernel, cfg=cfg, p=p)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G,),
        in_specs=[
            slot(er, LANES), slot(er, LANES), slot(er, LANES),
            slot(er, LANES),
            slot(nr, LANES), slot(nr, LANES), slot(nr, LANES),
            slot(nr, LANES), slot(lr, LANES),
            slot(er, LANES), slot(nr, LANES),
        ],
        out_specs=[
            slot(er, LANES), slot(nr, LANES),
            slot(p, D), slot(p, D), slot(1, p), slot(1, p),
        ],
    )
    # input indices count the scalar-prefetch operand (meta = 0)
    evl2, no2, pn, pa, dep, leaf = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        input_output_aliases={10: 0, 11: 1},
        interpret=interpret,
    )(meta, child_p, en_p, ew_p, ep_p, nn_p, ne_p, na_p, tm_p, lg_p,
      evl_p, no_p)
    return (
        cm.unpack_edges_arena(evl2, X, Fp),
        cm.unpack_nodes_arena(no2, X),
        pn, pa, dep[:, 0], leaf[:, 0],
    )


@functools.partial(jax.jit, static_argnames=("cfg", "p", "interpret"))
def select(cfg: TreeConfig, tree, p: int, interpret: bool = True):
    """Single-tree selection: the G=1 case of the arena kernel.  Returns
    (edge_VL', node_O', path_nodes, path_actions, depths, leaves) with
    logical (unpacked) shapes."""
    arena = jax.tree.map(lambda a: a[None], tree)
    evl, no, pn, pa, dep, leaf = select_arena(
        cfg, arena, jnp.ones((1,), jnp.int32), p, interpret=interpret)
    return evl[0], no[0], pn[0], pa[0], dep[0], leaf[0]
