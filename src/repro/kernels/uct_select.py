"""Pallas TPU kernel: Tree-Parallel Selection + virtual-loss apply.

This is the accelerator core of the paper (§IV-B/C/D) adapted to TPU:

  paper FPGA                         | this kernel
  -----------------------------------+----------------------------------
  per-level SRAM banks, 1-cycle read | UCT packed row-aligned in VMEM
  subtree pipelines (1 worker/stage) | fori_loop over workers: identical
                                     | ordering semantics, VMEM-resident
  CLUT comparator tree at the root   | masked 128-lane VPU argmax
  fixed-point single-cycle compare   | Qm.16 int32 scores (exact compare)
  backup memoization buffer          | path_nodes/path_actions outputs

The whole UCT (all edge/node statistic arrays) is one VMEM working set —
"T_mem = 1 cycle" becomes "zero HBM traffic after tile load".  Worker
ordering is preserved exactly (worker k sees the virtual loss of workers
< k), so outputs are bit-identical to the sequential CPU program; the
kernel shares the scoring spec of repro.core.scoring verbatim.

The kernel is written for the TPU backend (2-D iotas, row-granular RMW,
power-of-two edge blocks) and validated in interpret mode on CPU; scalar
operands (root id, tree size) ride in [1,1] VMEM rows — a production build
would hoist them to SMEM scalar prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fixedpoint as fx
from repro.core import scoring
from repro.core.tree import NULL, TreeConfig
from repro.kernels import common as cm

LANES = cm.LANES


def _select_kernel(
    # inputs
    root_ref,        # [1,1] i32
    child_ref,       # [Er, 128] i32 packed edges
    edge_n_ref,      # [Er, 128] i32
    edge_w_ref,      # [Er, 128] i32 (Qm.16)
    edge_p_ref,      # [Er, 128] i32 (Qm.16)
    node_n_ref,      # [Nr, 128] i32 packed nodes
    num_exp_ref,     # [Nr, 128] i32
    num_act_ref,     # [Nr, 128] i32
    terminal_ref,    # [Nr, 128] i32
    log_ref,         # [Lr, 128] f32 packed ln table
    evl_in_ref,      # [Er, 128] i32 (aliased with edge_vl_ref)
    no_in_ref,       # [Nr, 128] i32 (aliased with node_o_ref)
    # outputs
    edge_vl_ref,     # [Er, 128] i32
    node_o_ref,      # [Nr, 128] i32
    pn_ref,          # [p, D] i32
    pa_ref,          # [p, D] i32
    depth_ref,       # [1, p] i32
    leaf_ref,        # [1, p] i32
    *,
    cfg: TreeConfig,
    p: int,
):
    Fp, D = cfg.Fp, cfg.D
    lane = cm.lane_iota()
    i32 = jnp.int32

    # Aliased buffers: physically a no-op copy; keeps the kernel correct
    # when run un-aliased (e.g. some interpret configurations).
    edge_vl_ref[...] = evl_in_ref[...]
    node_o_ref[...] = no_in_ref[...]
    # init path outputs to NULL
    pn_ref[...] = jnp.full((p, D), NULL, i32)
    pa_ref[...] = jnp.full((p, D), NULL, i32)
    root = root_ref[0, 0]

    def worker(j, _):
        cm.sadd(node_o_ref, root, 1)

        def level(d, carry):
            node, depth = carry
            n_exp = cm.sload(num_exp_ref, node)
            n_act = cm.sload(num_act_ref, node)
            term = cm.sload(terminal_ref, node)
            leafp = scoring.is_leaf(
                cfg, num_expanded=n_exp, num_actions=n_act,
                terminal=term, depth=depth, xp=jnp)
            active = (~leafp) & (d == depth)

            row = node * Fp // LANES
            off = node * Fp % LANES
            child_r = cm.load_row(child_ref, row)
            seg = (lane >= off) & (lane < off + Fp)
            child_m = jnp.where(seg, child_r, NULL)

            n_n = cm.sload(node_n_ref, node)
            n_o = cm.sload(node_o_ref, node)
            ns = n_n + n_o if cfg.vl_mode == "wu" else n_n
            ns = jnp.minimum(ns, i32(2 * cfg.X + 3))
            log_ns = cm.sload(log_ref, ns)

            scores = scoring.edge_scores_fx(
                cfg,
                child=child_m,
                edge_N=cm.load_row(edge_n_ref, row),
                edge_W=cm.load_row(edge_w_ref, row),
                edge_VL=cm.load_row(edge_vl_ref, row),
                edge_P=cm.load_row(edge_p_ref, row),
                node_N=n_n[None, None],
                node_O=n_o[None, None],
                num_actions=(off + n_act)[None, None],
                xp=jnp,
                lane=lane,                      # lane < off + n_act validity
                log_ns=log_ns[None, None],
            )
            # VPU-native worker distributor (paper's CLUT, §IV-D): masked
            # first-max argmax over the full 128-lane row, as two 2-D
            # reductions (max, then min-index-of-max) — Mosaic-friendly.
            m = jnp.max(scores)
            g = jnp.min(jnp.where(scores == m, lane, i32(LANES))).astype(i32)

            # virtual-loss apply (Alg. 1 line 5) — row RMW
            vl_row = cm.load_row(edge_vl_ref, row)
            inc = jnp.where(active & (lane == g), i32(1), i32(0))
            cm.store_row(edge_vl_ref, row, vl_row + inc)

            # memoization buffer write (paper §IV-E)
            d_lane = jax.lax.broadcasted_iota(i32, (1, D), 1)
            pn_row = pl.load(pn_ref, (pl.dslice(j, 1), slice(None)))
            pa_row = pl.load(pa_ref, (pl.dslice(j, 1), slice(None)))
            sel_d = active & (d_lane == d)
            pl.store(pn_ref, (pl.dslice(j, 1), slice(None)),
                     jnp.where(sel_d, node, pn_row))
            pl.store(pa_ref, (pl.dslice(j, 1), slice(None)),
                     jnp.where(sel_d, g - off, pa_row))

            nxt = cm.extract_lane(child_m, g)
            node = jnp.where(active, nxt, node)
            cm.sadd(node_o_ref, node, jnp.where(active, i32(1), i32(0)))
            depth = depth + jnp.where(active, i32(1), i32(0))
            return node, depth

        node, depth = jax.lax.fori_loop(0, D, level, (root, i32(0)))
        dep_row = pl.load(depth_ref, (slice(None), slice(None)))
        leaf_row = pl.load(leaf_ref, (slice(None), slice(None)))
        sel_j = jax.lax.broadcasted_iota(i32, (1, p), 1) == j
        pl.store(depth_ref, (slice(None), slice(None)),
                 jnp.where(sel_j, depth, dep_row))
        pl.store(leaf_ref, (slice(None), slice(None)),
                 jnp.where(sel_j, node, leaf_row))
        return 0

    depth_ref[...] = jnp.zeros((1, p), i32)
    leaf_ref[...] = jnp.zeros((1, p), i32)
    jax.lax.fori_loop(0, p, worker, 0)


@functools.partial(jax.jit, static_argnames=("cfg", "p", "interpret"))
def select(cfg: TreeConfig, tree, p: int, interpret: bool = True):
    """Run the selection kernel.  Returns (edge_VL', node_O', path_nodes,
    path_actions, depths, leaves) with logical (unpacked) shapes."""
    Fp, X, D = cfg.Fp, tree.X, cfg.D
    child_p = cm.pack_edges(tree.child, Fp)
    en_p = cm.pack_edges(tree.edge_N, Fp)
    ew_p = cm.pack_edges(tree.edge_W, Fp)
    ep_p = cm.pack_edges(tree.edge_P, Fp)
    evl_p = cm.pack_edges(tree.edge_VL, Fp)
    nn_p = cm.pack_nodes(tree.node_N)
    no_p = cm.pack_nodes(tree.node_O)
    ne_p = cm.pack_nodes(tree.num_expanded)
    na_p = cm.pack_nodes(tree.num_actions)
    tm_p = cm.pack_nodes(tree.terminal)
    lg_p = cm.pack_nodes(tree.log_table)
    root = tree.root.reshape(1, 1)

    er, nr, lr = child_p.shape[0], nn_p.shape[0], lg_p.shape[0]
    full = lambda shp: pl.BlockSpec(shp, lambda: tuple(0 for _ in shp))
    out_shapes = (
        jax.ShapeDtypeStruct((er, LANES), jnp.int32),   # edge_VL'
        jax.ShapeDtypeStruct((nr, LANES), jnp.int32),   # node_O'
        jax.ShapeDtypeStruct((p, D), jnp.int32),        # path_nodes
        jax.ShapeDtypeStruct((p, D), jnp.int32),        # path_actions
        jax.ShapeDtypeStruct((1, p), jnp.int32),        # depths
        jax.ShapeDtypeStruct((1, p), jnp.int32),        # leaves
    )
    kernel = functools.partial(_select_kernel, cfg=cfg, p=p)
    evl2, no2, pn, pa, dep, leaf = pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        in_specs=[
            full((1, 1)),
            full((er, LANES)), full((er, LANES)), full((er, LANES)),
            full((er, LANES)),
            full((nr, LANES)), full((nr, LANES)), full((nr, LANES)),
            full((nr, LANES)), full((lr, LANES)),
            full((er, LANES)), full((nr, LANES)),
        ],
        out_specs=[
            full((er, LANES)), full((nr, LANES)),
            full((p, D)), full((p, D)), full((1, p)), full((1, p)),
        ],
        input_output_aliases={10: 0, 11: 1},
        interpret=interpret,
    )(root, child_p, en_p, ew_p, ep_p, nn_p, ne_p, na_p, tm_p, lg_p,
      evl_p, no_p)
    return (
        cm.unpack_edges(evl2, X, Fp),
        cm.unpack_nodes(no2, X),
        pn, pa, dep[0], leaf[0],
    )
