"""Pallas TPU kernel: BackUp from memoized selection paths (paper §IV-E).

The paper attaches a (D-1)-word memoization buffer to each worker during
Selection so BackUp never re-walks the tree; the FPGA then streams workers
through the pipeline, updating one level per stage.  Here the memoized
paths arrive as the `path_nodes`/`path_actions` arrays produced by the
selection kernel, and every update is an exact Qm.16 integer add performed
as a full-row VMEM read-modify-write.

Integer adds commute, so although this kernel loops workers in order (to
mirror the paper's pipeline), the result is independent of worker order —
the property the vectorized jnp fallback (core.intree.backup_batch)
exploits; both are bit-identical to the sequential CPU program.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.tree import NULL, TreeConfig
from repro.kernels import common as cm

LANES = cm.LANES


def _backup_kernel(
    # inputs
    pn_ref,        # [p, D] i32 memoized path nodes
    pa_ref,        # [p, D] i32 memoized path actions
    depth_ref,     # [1, p] i32
    leaf_ref,      # [1, p] i32
    ea_ref,        # [1, p] i32 expand_action
    simn_ref,      # [1, p] i32 sim nodes
    val_ref,       # [1, p] i32 Qm.16 values
    en_in_ref, ew_in_ref, evl_in_ref, nn_in_ref, no_in_ref,   # aliased ins
    # outputs (aliased)
    edge_n_ref,    # [Er, 128] i32
    edge_w_ref,    # [Er, 128] i32
    edge_vl_ref,   # [Er, 128] i32
    node_n_ref,    # [Nr, 128] i32
    node_o_ref,    # [Nr, 128] i32
    *,
    cfg: TreeConfig,
    p: int,
    alternating: bool,
):
    Fp, D = cfg.Fp, cfg.D
    i32 = jnp.int32
    lane = cm.lane_iota()

    edge_n_ref[...] = en_in_ref[...]
    edge_w_ref[...] = ew_in_ref[...]
    edge_vl_ref[...] = evl_in_ref[...]
    node_n_ref[...] = nn_in_ref[...]
    node_o_ref[...] = no_in_ref[...]

    def row_of(x):  # [1,p] ref scalar extraction
        return lambda j: cm.extract_lane(pl.load(x, (slice(None), slice(None))), j)

    get_depth, get_leaf = row_of(depth_ref), row_of(leaf_ref)
    get_ea, get_sim, get_val = row_of(ea_ref), row_of(simn_ref), row_of(val_ref)

    def worker(j, _):
        depth = get_depth(j)
        leaf = get_leaf(j)
        ea = get_ea(j)
        sim = get_sim(j)
        v = get_val(j)
        expanded = (ea >= 0) & jnp.asarray(not cfg.expand_all)
        sim_depth = depth + jnp.where(expanded, i32(1), i32(0))

        def level(d, _):
            pn_row = pl.load(pn_ref, (pl.dslice(j, 1), slice(None)))
            pa_row = pl.load(pa_ref, (pl.dslice(j, 1), slice(None)))
            node = cm.extract_lane(pn_row, d)
            a = cm.extract_lane(pa_row, d)
            on = (d < depth) & (node != NULL)
            node = jnp.where(on, node, i32(0))   # keep addresses in-bounds
            a = jnp.where(on, a, i32(0))         # (masked updates below)
            inc = jnp.where(on, i32(1), i32(0))
            if alternating:
                sign = jnp.where((sim_depth - d) % 2 == 1, i32(-1), i32(1))
            else:
                sign = i32(1)
            row = node * Fp // LANES
            tgt = (lane == node * Fp % LANES + a)
            upd = jnp.where(tgt, inc, i32(0))
            cm.store_row(edge_n_ref, row, cm.load_row(edge_n_ref, row) + upd)
            cm.store_row(edge_w_ref, row,
                         cm.load_row(edge_w_ref, row) + upd * sign * v)
            cm.store_row(edge_vl_ref, row,
                         cm.load_row(edge_vl_ref, row) - upd)
            cm.sadd(node_n_ref, node, inc)
            cm.sadd(node_o_ref, node, -inc)
            return 0

        jax.lax.fori_loop(0, D, level, 0)
        cm.sadd(node_n_ref, leaf, 1)
        cm.sadd(node_o_ref, leaf, -1)

        # expansion edge (single-expand mode): seed sim node's in-edge
        e_inc = jnp.where(expanded, i32(1), i32(0))
        if alternating:
            e_sign = jnp.where((sim_depth - depth) % 2 == 1, i32(-1), i32(1))
        else:
            e_sign = i32(1)
        row = leaf * Fp // LANES
        tgt = lane == leaf * Fp % LANES + ea
        upd = jnp.where(tgt, e_inc, i32(0))
        cm.store_row(edge_n_ref, row, cm.load_row(edge_n_ref, row) + upd)
        cm.store_row(edge_w_ref, row,
                     cm.load_row(edge_w_ref, row) + upd * e_sign * v)
        cm.sadd(node_n_ref, jnp.where(expanded, sim, leaf),
                jnp.where(expanded, i32(1), i32(0)))
        return 0

    jax.lax.fori_loop(0, p, worker, 0)


@functools.partial(jax.jit, static_argnames=("cfg", "p", "alternating", "interpret"))
def backup(cfg: TreeConfig, tree, pn, pa, depths, leaves, expand_action,
           sim_nodes, values_fx, p: int, alternating: bool = False,
           interpret: bool = True):
    """Run the backup kernel; returns updated (edge_N, edge_W, edge_VL,
    node_N, node_O) in logical shapes."""
    Fp, X = cfg.Fp, tree.X
    en_p = cm.pack_edges(tree.edge_N, Fp)
    ew_p = cm.pack_edges(tree.edge_W, Fp)
    evl_p = cm.pack_edges(tree.edge_VL, Fp)
    nn_p = cm.pack_nodes(tree.node_N)
    no_p = cm.pack_nodes(tree.node_O)
    er, nr = en_p.shape[0], nn_p.shape[0]
    D = cfg.D

    full = lambda shp: pl.BlockSpec(shp, lambda: tuple(0 for _ in shp))
    out_shapes = tuple(
        jax.ShapeDtypeStruct((er, LANES), jnp.int32) for _ in range(3)
    ) + tuple(jax.ShapeDtypeStruct((nr, LANES), jnp.int32) for _ in range(2))
    kernel = functools.partial(
        _backup_kernel, cfg=cfg, p=p, alternating=alternating)
    en2, ew2, evl2, nn2, no2 = pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        in_specs=[
            full((p, D)), full((p, D)), full((1, p)), full((1, p)),
            full((1, p)), full((1, p)), full((1, p)),
            full((er, LANES)), full((er, LANES)), full((er, LANES)),
            full((nr, LANES)), full((nr, LANES)),
        ],
        out_specs=[
            full((er, LANES)), full((er, LANES)), full((er, LANES)),
            full((nr, LANES)), full((nr, LANES)),
        ],
        input_output_aliases={7: 0, 8: 1, 9: 2, 10: 3, 11: 4},
        interpret=interpret,
    )(
        pn, pa, depths.reshape(1, p), leaves.reshape(1, p),
        expand_action.reshape(1, p), sim_nodes.reshape(1, p),
        values_fx.reshape(1, p),
        en_p, ew_p, evl_p, nn_p, no_p,
    )
    return (
        cm.unpack_edges(en2, X, Fp),
        cm.unpack_edges(ew2, X, Fp),
        cm.unpack_edges(evl2, X, Fp),
        cm.unpack_nodes(nn2, X),
        cm.unpack_nodes(no2, X),
    )
