"""Pallas TPU kernel: BackUp from memoized selection paths (paper §IV-E).

The paper attaches a (D-1)-word memoization buffer to each worker during
Selection so BackUp never re-walks the tree; the FPGA then streams workers
through the pipeline, updating one level per stage.  Here the memoized
paths arrive as the `path_nodes`/`path_actions` arrays produced by the
selection kernel, and every update is an exact Qm.16 integer add performed
as a full-row VMEM read-modify-write.

Arena-native like the selection kernel: a ``[G]`` grid maps one program to
each tree slot (its packed statistic arrays block-mapped into VMEM, its
scalars — here just the active flag — scalar-prefetched in SMEM), so all
G trees back up in one launch and an inactive slot's program is a no-op
pass-through.  Single-tree backup is the G=1 case.

Integer adds commute, so although this kernel loops workers in order (to
mirror the paper's pipeline), the result is independent of worker order —
the property the vectorized jnp fallback (core.intree.backup_batch)
exploits; both are bit-identical to the sequential CPU program.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tree import NULL, TreeConfig
from repro.kernels import common as cm
from repro.kernels.uct_select import META_ACTIVE, META_WORDS

LANES = cm.LANES


def _backup_kernel(
    # scalar prefetch
    meta_ref,      # [G, 3] i32 in SMEM: (root, size, active) per slot
    # inputs (per-slot blocks)
    pn_ref,        # [p, D] i32 memoized path nodes
    pa_ref,        # [p, D] i32 memoized path actions
    depth_ref,     # [1, p] i32
    leaf_ref,      # [1, p] i32
    ea_ref,        # [1, p] i32 expand_action
    simn_ref,      # [1, p] i32 sim nodes
    val_ref,       # [1, p] i32 Qm.16 values
    en_in_ref, ew_in_ref, evl_in_ref, nn_in_ref, no_in_ref,   # aliased ins
    # outputs (aliased)
    edge_n_ref,    # [Er, 128] i32
    edge_w_ref,    # [Er, 128] i32
    edge_vl_ref,   # [Er, 128] i32
    node_n_ref,    # [Nr, 128] i32
    node_o_ref,    # [Nr, 128] i32
    *,
    cfg: TreeConfig,
    p: int,
    alternating: bool,
):
    Fp, D = cfg.Fp, cfg.D
    i32 = jnp.int32
    lane = cm.lane_iota()
    g = pl.program_id(0)
    slot_active = meta_ref[g, META_ACTIVE]

    edge_n_ref[...] = en_in_ref[...]
    edge_w_ref[...] = ew_in_ref[...]
    edge_vl_ref[...] = evl_in_ref[...]
    node_n_ref[...] = nn_in_ref[...]
    node_o_ref[...] = no_in_ref[...]

    def row_of(x):  # [1,p] ref scalar extraction
        return lambda j: cm.extract_lane(pl.load(x, (slice(None), slice(None))), j)

    get_depth, get_leaf = row_of(depth_ref), row_of(leaf_ref)
    get_ea, get_sim, get_val = row_of(ea_ref), row_of(simn_ref), row_of(val_ref)

    def worker(j, _):
        depth = get_depth(j)
        leaf = get_leaf(j)
        ea = get_ea(j)
        sim = get_sim(j)
        v = get_val(j)
        expanded = (ea >= 0) & jnp.asarray(not cfg.expand_all)
        sim_depth = depth + jnp.where(expanded, i32(1), i32(0))

        def level(d, _):
            pn_row = pl.load(pn_ref, (pl.dslice(j, 1), slice(None)))
            pa_row = pl.load(pa_ref, (pl.dslice(j, 1), slice(None)))
            node = cm.extract_lane(pn_row, d)
            a = cm.extract_lane(pa_row, d)
            on = (d < depth) & (node != NULL)
            node = jnp.where(on, node, i32(0))   # keep addresses in-bounds
            a = jnp.where(on, a, i32(0))         # (masked updates below)
            inc = jnp.where(on, i32(1), i32(0))
            if alternating:
                sign = jnp.where((sim_depth - d) % 2 == 1, i32(-1), i32(1))
            else:
                sign = i32(1)
            row = node * Fp // LANES
            tgt = (lane == node * Fp % LANES + a)
            upd = jnp.where(tgt, inc, i32(0))
            cm.store_row(edge_n_ref, row, cm.load_row(edge_n_ref, row) + upd)
            cm.store_row(edge_w_ref, row,
                         cm.load_row(edge_w_ref, row) + upd * sign * v)
            cm.store_row(edge_vl_ref, row,
                         cm.load_row(edge_vl_ref, row) - upd)
            cm.sadd(node_n_ref, node, inc)
            cm.sadd(node_o_ref, node, -inc)
            return 0

        jax.lax.fori_loop(0, D, level, 0)
        cm.sadd(node_n_ref, leaf, 1)
        cm.sadd(node_o_ref, leaf, -1)

        # expansion edge (single-expand mode): seed sim node's in-edge
        e_inc = jnp.where(expanded, i32(1), i32(0))
        if alternating:
            e_sign = jnp.where((sim_depth - depth) % 2 == 1, i32(-1), i32(1))
        else:
            e_sign = i32(1)
        row = leaf * Fp // LANES
        tgt = lane == leaf * Fp % LANES + ea
        upd = jnp.where(tgt, e_inc, i32(0))
        cm.store_row(edge_n_ref, row, cm.load_row(edge_n_ref, row) + upd)
        cm.store_row(edge_w_ref, row,
                     cm.load_row(edge_w_ref, row) + upd * e_sign * v)
        cm.sadd(node_n_ref, jnp.where(expanded, sim, leaf),
                jnp.where(expanded, i32(1), i32(0)))
        return 0

    # inactive slot -> no-op program (pass-through copies only)
    @pl.when(slot_active == 1)
    def _run_workers():
        jax.lax.fori_loop(0, p, worker, 0)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "p", "alternating", "interpret"))
def backup_arena(cfg: TreeConfig, arena, active, pn, pa, depths, leaves,
                 expand_action, sim_nodes, values_fx, p: int,
                 alternating: bool = False, interpret: bool = True):
    """Backup kernel over a G-slot arena.  All per-worker inputs carry a
    leading [G] axis ([G, p, D] paths, [G, p] scalars); `active` is a [G]
    mask.  Returns updated (edge_N, edge_W, edge_VL, node_N, node_O) in
    logical shapes [G, X, Fp] / [G, X]; inactive slots are bit-identical.
    """
    Fp = cfg.Fp
    G, X = arena.child.shape[0], arena.child.shape[1]
    en_p = cm.pack_edges_arena(arena.edge_N, Fp)
    ew_p = cm.pack_edges_arena(arena.edge_W, Fp)
    evl_p = cm.pack_edges_arena(arena.edge_VL, Fp)
    nn_p = cm.pack_nodes_arena(arena.node_N)
    no_p = cm.pack_nodes_arena(arena.node_O)
    er, nr = en_p.shape[1], nn_p.shape[1]
    D = cfg.D
    meta = jnp.zeros((G, META_WORDS), jnp.int32)
    meta = meta.at[:, META_ACTIVE].set(jnp.asarray(active, jnp.int32))

    slot = lambda *shp: pl.BlockSpec((None,) + shp,
                                     lambda g, m: (g,) + (0,) * len(shp))
    out_shapes = tuple(
        jax.ShapeDtypeStruct((G, er, LANES), jnp.int32) for _ in range(3)
    ) + tuple(
        jax.ShapeDtypeStruct((G, nr, LANES), jnp.int32) for _ in range(2))
    kernel = functools.partial(
        _backup_kernel, cfg=cfg, p=p, alternating=alternating)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G,),
        in_specs=[
            slot(p, D), slot(p, D), slot(1, p), slot(1, p),
            slot(1, p), slot(1, p), slot(1, p),
            slot(er, LANES), slot(er, LANES), slot(er, LANES),
            slot(nr, LANES), slot(nr, LANES),
        ],
        out_specs=[
            slot(er, LANES), slot(er, LANES), slot(er, LANES),
            slot(nr, LANES), slot(nr, LANES),
        ],
    )
    # input indices count the scalar-prefetch operand (meta = 0)
    en2, ew2, evl2, nn2, no2 = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shapes,
        input_output_aliases={8: 0, 9: 1, 10: 2, 11: 3, 12: 4},
        interpret=interpret,
    )(
        meta, pn, pa, depths.reshape(G, 1, p), leaves.reshape(G, 1, p),
        expand_action.reshape(G, 1, p), sim_nodes.reshape(G, 1, p),
        values_fx.reshape(G, 1, p),
        en_p, ew_p, evl_p, nn_p, no_p,
    )
    return (
        cm.unpack_edges_arena(en2, X, Fp),
        cm.unpack_edges_arena(ew2, X, Fp),
        cm.unpack_edges_arena(evl2, X, Fp),
        cm.unpack_nodes_arena(nn2, X),
        cm.unpack_nodes_arena(no2, X),
    )


@functools.partial(jax.jit,
                   static_argnames=("cfg", "p", "alternating", "interpret"))
def backup(cfg: TreeConfig, tree, pn, pa, depths, leaves, expand_action,
           sim_nodes, values_fx, p: int, alternating: bool = False,
           interpret: bool = True):
    """Single-tree backup: the G=1 case of the arena kernel.  Returns
    updated (edge_N, edge_W, edge_VL, node_N, node_O) in logical shapes."""
    arena = jax.tree.map(lambda a: a[None], tree)
    en, ew, evl, nn, no = backup_arena(
        cfg, arena, jnp.ones((1,), jnp.int32), pn[None], pa[None],
        jnp.asarray(depths)[None], jnp.asarray(leaves)[None],
        jnp.asarray(expand_action)[None], jnp.asarray(sim_nodes)[None],
        jnp.asarray(values_fx)[None], p=p, alternating=alternating,
        interpret=interpret)
    return en[0], ew[0], evl[0], nn[0], no[0]
