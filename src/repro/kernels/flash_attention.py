"""Pallas TPU kernel: flash attention (LM simulation-backend hot-spot).

The paper's Gomoku benchmark replaces software simulation with DNN
inference; in this framework the simulation backend generalizes to LM
`serve_step`, whose prefill is MXU-bound attention.  This kernel is the
TPU-optimized path for that hot-spot: classic FlashAttention-2 blocking
with explicit BlockSpec VMEM tiles, online softmax, causal and
sliding-window masking, GQA via grid-mapped KV heads.

Grid: (batch, q_heads, q_blocks); each program streams KV blocks for one
query tile.  Block shapes default to (128, head_dim) tiles — MXU-aligned
(multiples of 128 on the contracting/lane dims for f32/bf16).

ref.py oracle: repro.models.attention.naive_attention (same math, jnp).
Validated with interpret=True across shape/dtype/mask sweeps in
tests/test_flash_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.common import canonical_index

NEG = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal, window, scale,
                  blk_q, blk_k, seq_k, seq_k_real):
    qi = pl.program_id(2)
    q = q_ref[0, :, :].astype(jnp.float32) * scale          # [blk_q, dh]
    nk = seq_k // blk_k

    m0 = jnp.full((blk_q, 1), NEG, jnp.float32)
    l0 = jnp.zeros((blk_q, 1), jnp.float32)
    a0 = jnp.zeros((blk_q, q.shape[-1]), jnp.float32)

    qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)

    def body(kj, carry):
        m_run, l_run, acc = carry
        # leading axis indexed with a length-1 dslice, not a bare int: the
        # interpreter's load-discharge rule rejects scalar ints in a mixed
        # index tuple (every 16-case sweep in tests/test_flash_kernel.py
        # crashed on it; kernel numerics were never the problem).  Starts
        # go through canonical_index so the tuple stays one dtype under
        # JAX_ENABLE_X64.
        kstart = canonical_index(kj * blk_k)
        k = pl.load(k_ref, (pl.dslice(canonical_index(0), 1),
                            pl.dslice(kstart, blk_k), slice(None)))[0]
        v = pl.load(v_ref, (pl.dslice(canonical_index(0), 1),
                            pl.dslice(kstart, blk_k), slice(None)))[0]
        s = q @ k.astype(jnp.float32).T                      # [blk_q, blk_k]
        kpos = kj * blk_k + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 1)
        msk = jnp.ones((blk_q, blk_k), jnp.bool_)
        if causal:
            msk &= kpos <= qpos
        if window is not None:
            msk &= kpos > qpos - window
        msk &= kpos < seq_k_real            # drop padded keys
        s = jnp.where(msk, s, NEG)
        m_new = jnp.maximum(m_run, jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_run * alpha + jnp.sum(p, -1, keepdims=True)
        acc = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc

    # causal: stop at the diagonal block; window: also skip blocks fully
    # left of the window.
    hi = jnp.minimum(nk, qi * blk_q // blk_k + 1) if causal else nk
    lo = 0
    if window is not None:
        lo = jnp.maximum(0, (qi * blk_q - window) // blk_k)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[0, :, :] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    blk_q=128, blk_k=128, interpret=True):
    """q: [B, Sq, H, dh]; k/v: [B, Sk, Hkv, dh] (H % Hkv == 0).
    Returns [B, Sq, H, dh].  Sq/Sk padded to block multiples internally."""
    B, Sq, H, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    # weak python float, not an np.float64 scalar: a strong f64 scale
    # would widen the whole online-softmax carry under JAX_ENABLE_X64
    scale = float(1.0 / np.sqrt(dh))

    nq = -(-Sq // blk_q)
    nk = -(-Sk // blk_k)
    Sqp, Skp = nq * blk_q, nk * blk_k
    qp = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0), (0, 0)))
    # pad keys beyond Sk are masked by causality for Sq<=Sk; for safety add
    # an explicit window-independent validity via causal/window masks only
    # when Skp == Sk; otherwise rely on qpos<=Sq padding being discarded.
    qh = qp.transpose(0, 2, 1, 3)        # [B, H, Sqp, dh]
    kh = kp.transpose(0, 2, 1, 3)        # [B, Hkv, Skp, dh]
    vh = vp.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, scale=scale,
        blk_q=blk_q, blk_k=blk_k, seq_k=Skp, seq_k_real=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, h, i: (b * H + h, i, 0)),
            pl.BlockSpec((1, Skp, dh), lambda b, h, i: (b * Hkv + h // g, 0, 0)),
            pl.BlockSpec((1, Skp, dh), lambda b, h, i: (b * Hkv + h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda b, h, i: (b * H + h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, dh), q.dtype),
        interpret=interpret,
    )(qh.reshape(B * H, Sqp, dh), kh.reshape(B * Hkv, Skp, dh),
      vh.reshape(B * Hkv, Skp, dh))
    out = out.reshape(B, H, Sqp, dh).transpose(0, 2, 1, 3)
    return out[:, :Sq]
