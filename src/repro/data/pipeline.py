"""Data pipeline: deterministic synthetic token stream + host prefetch.

Determinism contract (fault tolerance): batch(step) is a pure function of
(seed, step), so a restart from checkpoint step k replays the identical
stream — no data-state checkpointing needed.

The synthetic stream is a mixture of Zipf-distributed unigrams and short
repeated motifs, giving a learnable distribution (loss decreases) rather
than uniform noise — used by the end-to-end training example.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticTokens:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 zipf_a: float = 1.3, motif_len: int = 8, n_motifs: int = 64):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        rng = np.random.RandomState(seed)
        self.motifs = rng.randint(
            0, vocab, size=(n_motifs, motif_len)).astype(np.int32)
        self.zipf_a = zipf_a

    def batch_at(self, step: int) -> dict:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step) % (2**31 - 1))
        z = rng.zipf(self.zipf_a, size=(self.batch, self.seq + 1))
        toks = (z - 1) % self.vocab
        # overwrite random spans with motifs (predictable structure)
        n_spans = self.seq // 32
        for b in range(self.batch):
            idx = rng.randint(0, len(self.motifs), size=n_spans)
            pos = rng.randint(0, self.seq - self.motifs.shape[1],
                              size=n_spans)
            for m, p0 in zip(idx, pos):
                toks[b, p0 : p0 + self.motifs.shape[1]] = self.motifs[m]
        toks = toks.astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": np.ones((self.batch, self.seq), np.float32),
        }


class Prefetcher:
    """Host-side prefetch thread: overlaps batch synthesis/IO with device
    compute (depth-bounded queue)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            b = self.source.batch_at(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
